"""Streaming online-learning subsystem: SignatureCache + OnlineTrainer."""

import functools

import jax
import numpy as np
import pytest

from repro.data import TINY, generate
from repro.data.pipeline import SignatureStream, make_sharded_dataset
from repro.kernels import batch_signatures
from repro.models.linear import (LinearModel, accuracy, hashed_margin,
                                 sgd_svm_init, sgd_svm_step)
from repro.train import OnlineTrainer, SignatureCache, make_family

K, B, D_BITS = 128, 8, 16


@pytest.fixture(scope="module")
def shard_paths(tmp_path_factory):
    return make_sharded_dataset(TINY, str(tmp_path_factory.mktemp("shards")),
                                n_shards=3)


@pytest.mark.parametrize("scheme,densify", [
    ("2u", "rotation"),           # k-pass minhash (densify unused)
    ("oph", "rotation"),
    ("oph", "sentinel"),
    pytest.param("4u", "rotation", marks=pytest.mark.slow),
    pytest.param("oph-4u", "rotation", marks=pytest.mark.slow),
])
def test_signature_cache_replay_bitexact(shard_paths, tmp_path, scheme,
                                         densify):
    """pack -> write -> replay must be bit-exact vs a fresh stream."""
    key = jax.random.PRNGKey(0)
    fam = make_family(key, scheme, K, D_BITS, densify=densify)
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=str(tmp_path))
    epoch0 = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert cache.populated and cache.stats.shards == len(epoch0)
    replay = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    fresh = [(np.asarray(s), np.asarray(y))
             for s, y in SignatureStream(shard_paths, fam, b=B,
                                         chunk_size=64)]
    assert len(epoch0) == len(replay) == len(fresh) > 1
    for (s0, y0), (s1, y1), (s2, y2) in zip(epoch0, replay, fresh):
        np.testing.assert_array_equal(s0, s1)
        np.testing.assert_array_equal(s0, s2)
        np.testing.assert_array_equal(y0, y1)
        np.testing.assert_array_equal(y0, y2)
    # the cache is the paper's Table-2/§6 size reduction, on disk
    assert 0 < cache.stats.bytes_cached < cache.stats.bytes_original
    assert cache.stats.reduction() > 1.0


def test_cache_interrupted_epoch0_restarts_cleanly(shard_paths, tmp_path):
    """Abandoning epoch 0 mid-pass must not leave duplicate shards,
    inflated byte accounting, or stuck prefetch producer threads."""
    import threading
    import time

    fam = make_family(jax.random.PRNGKey(3), "2u", K, D_BITS)
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=str(tmp_path / "interrupted"))
    next(iter(cache))                  # peek one chunk, abandon the pass
    assert not cache.populated
    full = [np.asarray(s) for s, _ in cache]
    assert cache.populated and cache.stats.shards == len(full)
    replay = [np.asarray(s) for s, _ in cache]
    assert len(replay) == len(full)
    for a, b_ in zip(full, replay):
        np.testing.assert_array_equal(a, b_)

    # bytes_original must match a clean pass (no double-counted raw reads)
    clean = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=str(tmp_path / "clean"))
    for _ in clean:
        pass
    assert cache.stats.bytes_original == clean.stats.bytes_original
    assert cache.stats.bytes_cached == clean.stats.bytes_cached

    # abandoned producers must terminate, not stay blocked on a full queue
    deadline = time.monotonic() + 5.0
    while (any(t.name == "prefetch-producer" for t in threading.enumerate())
           and time.monotonic() < deadline):
        time.sleep(0.05)
    assert not any(t.name == "prefetch-producer"
                   for t in threading.enumerate())


def test_online_trainer_matches_handrolled_loop(shard_paths):
    """OnlineTrainer over the stream == the hand-rolled in-memory loop."""
    train, test = generate(TINY)
    fam = make_family(jax.random.PRNGKey(7), "2u", K, D_BITS)
    sig_tr = batch_signatures(train, fam, b=B)
    sig_te = batch_signatures(test, fam, b=B)

    state = sgd_svm_init(K * 2**B, avg_start=100.0)
    step = jax.jit(functools.partial(sgd_svm_step, lam=1e-4, eta0=0.5, b=B,
                                     average=True))
    for _ in range(5):
        for i in range(0, train.n, 16):
            state = step(state, sig_tr[i:i + 16], train.labels[i:i + 16])
    acc_hand = float(accuracy(state.model, sig_te, test.labels,
                              feature_kind="hashed", b=B))

    trainer = OnlineTrainer(k=K, b=B, average=True, lam=1e-4, eta0=0.5,
                            batch_size=16, avg_start=100.0)
    cache = SignatureCache(SignatureStream(shard_paths, fam, b=B,
                                           chunk_size=64))
    trainer.fit(cache, 5)
    acc_stream = float(accuracy(trainer.state.model, sig_te, test.labels,
                                feature_kind="hashed", b=B))
    assert acc_hand > 0.8 and acc_stream > 0.8
    assert abs(acc_hand - acc_stream) < 0.05, (acc_hand, acc_stream)


def test_epoch_stats_cache_replay_cheaper(shard_paths):
    """Cached-replay epochs must load strictly faster than the hash epoch."""
    fam = make_family(jax.random.PRNGKey(1), "oph", K, D_BITS)
    cache = SignatureCache(SignatureStream(shard_paths, fam, b=B,
                                           chunk_size=64))
    trainer = OnlineTrainer(k=K, b=B)
    _, stats, _ = trainer.fit(cache, 3)
    assert [s.source for s in stats] == ["hash", "cache", "cache"]
    assert stats[1].load_s < stats[0].load_s
    assert stats[2].load_s < stats[0].load_s
    assert stats[0].kernel_s > 0 and stats[1].kernel_s == 0
    assert 0 < stats[1].bytes_read < stats[0].bytes_read
    assert all(s.examples == stats[0].examples for s in stats)
    # warm continuation: returned lists cover this call only, and align
    _, stats2, evals2 = trainer.fit(cache, 1)
    assert len(stats2) == len(evals2) == 1
    assert stats2[0].epoch == 3 and stats2[0].source == "cache"
    assert len(trainer.epoch_stats) == 4


@pytest.mark.parametrize("kind", ["svm", "logistic"])
def test_trainer_kinds_and_sentinel_scheme(shard_paths, kind):
    """SVM + logistic both learn; sentinel OPH trains via zero-coding."""
    _, test = generate(TINY)
    fam = make_family(jax.random.PRNGKey(2), "oph", K, D_BITS,
                      densify="sentinel")
    sig_te = batch_signatures(test, fam, b=B)
    trainer = OnlineTrainer(k=K, b=B, kind=kind)
    stream = SignatureStream(shard_paths, fam, b=B, chunk_size=64)
    _, _, evals = trainer.fit(
        stream, 5, eval_fn=lambda t: t.evaluate(sig_te, test.labels))
    assert evals[-1] > 0.8, evals


def test_cache_close_cleans_owned_temp_dir(shard_paths, tmp_path):
    """close() removes shards; owned (mkdtemp) dirs are deleted, user
    dirs survive -- the per-run temp-dir leak is gone."""
    import os
    fam = make_family(jax.random.PRNGKey(5), "2u", K, D_BITS)
    cache = SignatureCache(SignatureStream(shard_paths, fam, b=B,
                                           chunk_size=64))   # owned tmp dir
    for _ in cache:
        pass
    owned_dir = cache.cache_dir
    assert os.path.isdir(owned_dir) and cache.paths
    cache.close()
    assert not os.path.exists(owned_dir)
    with pytest.raises(RuntimeError):
        next(iter(cache))

    user_dir = str(tmp_path / "user_cache")
    with SignatureCache(SignatureStream(shard_paths, fam, b=B,
                                        chunk_size=64),
                        cache_dir=user_dir) as cache2:
        for _ in cache2:
            pass
        assert cache2.paths
    assert os.path.isdir(user_dir)                   # user dir survives
    assert not os.listdir(user_dir)                  # but shards are gone

    # trainer-level ownership: close() cascades to consumed sources
    cache3 = SignatureCache(SignatureStream(shard_paths, fam, b=B,
                                            chunk_size=64))
    with OnlineTrainer(k=K, b=B) as trainer:
        trainer.fit(cache3, 1)
    assert cache3.closed and not os.path.exists(cache3.cache_dir)


def test_cache_max_bytes_evicts_tail_but_stays_bitexact(shard_paths,
                                                        tmp_path):
    """A byte budget caps the shard footprint; replay re-hashes the
    uncached tail and stays bit-exact vs a fresh stream."""
    fam = make_family(jax.random.PRNGKey(6), "oph", K, D_BITS)
    fresh = [(np.asarray(s), np.asarray(y))
             for s, y in SignatureStream(shard_paths, fam, b=B,
                                         chunk_size=64)]
    assert len(fresh) > 1
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=str(tmp_path), max_cache_bytes=1)  # only chunk 0 fits
    epoch0 = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert cache.stats.shards == 1 == len(cache.paths)
    assert cache.stats.uncached_chunks == len(fresh) - 1
    assert cache.stats.examples == sum(s.shape[0] for s, _ in fresh)
    replay = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert len(epoch0) == len(replay) == len(fresh)
    for (s0, y0), (s1, y1), (s2, y2) in zip(epoch0, replay, fresh):
        np.testing.assert_array_equal(s0, s2)
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(y0, y2)
        np.testing.assert_array_equal(y1, y2)


def test_packed_stream_trains_like_unpacked(shard_paths):
    """PackedSignatures chunks (wire words + in-step unpack) produce the
    exact same SGD trajectory as unpacked signatures."""
    _, test = generate(TINY)
    for densify in ("rotation", "sentinel"):
        fam = make_family(jax.random.PRNGKey(8), "oph", K, D_BITS,
                          densify=densify)
        sig_te = batch_signatures(test, fam, b=B)
        accs = {}
        for packed in (False, True):
            stream = SignatureStream(shard_paths, fam, b=B, chunk_size=64,
                                     packed=packed)
            trainer = OnlineTrainer(k=K, b=B)
            trainer.fit(stream, 2)
            accs[packed] = trainer
        w0 = np.asarray(accs[False].state.model.w)
        w1 = np.asarray(accs[True].state.model.w)
        np.testing.assert_array_equal(w0, w1)
        if densify == "rotation":        # sentinel needs ~5 epochs to learn
            acc = accs[True].evaluate(sig_te, test.labels)
            assert acc > 0.8, (densify, acc)


def test_packed_cache_replay_bitexact_and_small(shard_paths, tmp_path):
    """Packed stream -> .sig cache -> replay: bit-exact, and the sentinel
    payload is exactly (b+1)/32 of the uint32 baseline."""
    from repro.kernels import PackedSignatures
    fam = make_family(jax.random.PRNGKey(9), "oph", K, D_BITS,
                      densify="sentinel")
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64, packed=True),
        cache_dir=str(tmp_path))
    epoch0 = [(s, np.asarray(y)) for s, y in cache]
    replay = [(s, np.asarray(y)) for s, y in cache]
    assert len(epoch0) == len(replay) > 1
    for (p0, y0), (p1, y1) in zip(epoch0, replay):
        assert isinstance(p0, PackedSignatures)
        assert isinstance(p1, PackedSignatures)
        assert (p1.k, p1.b, p1.sentinel) == (K, B, True)
        np.testing.assert_array_equal(np.asarray(p0.data),
                                      np.asarray(p1.data))
        np.testing.assert_array_equal(y0, y1)
    n = cache.stats.examples
    assert cache.stats.bytes_payload == \
        n * 4 * ((K * (B + 1) + 31) // 32)           # k*(b+1) bits/example
    assert cache.stats.bytes_payload <= (B + 1) / 32 * (n * K * 4)


def test_sentinel_zero_coding_margin():
    """EMPTY bins contribute nothing to the Eq.(5) margin."""
    from repro.core.oph import EMPTY
    k, b = 8, 4
    rng = np.random.default_rng(0)
    sig = rng.integers(0, 1 << b, size=(5, k)).astype(np.uint32)
    w = jax.numpy.asarray(rng.normal(size=(k * (1 << b),)).astype(np.float32))
    model = LinearModel(w=w, bias=jax.numpy.float32(0.1))
    full = np.asarray(hashed_margin(model, jax.numpy.asarray(sig), b))
    # blank one bin per row; the margin must drop by exactly that bin's w
    sig_empty = sig.copy()
    sig_empty[:, 3] = np.uint32(EMPTY)
    part = np.asarray(hashed_margin(model, jax.numpy.asarray(sig_empty), b))
    scale = 1.0 / np.sqrt(k)
    expected = full - scale * np.asarray(w)[3 * (1 << b) + sig[:, 3]]
    np.testing.assert_allclose(part, expected, rtol=1e-5, atol=1e-6)


def test_cache_budget_replay_skips_cached_prefix_io(shard_paths, tmp_path):
    """A budget-truncated cache must NOT re-read the raw shards behind
    the cached prefix on replay: the tail read resumes at the first
    uncached chunk's shard offset recorded at populate time."""
    import os

    fam = make_family(jax.random.PRNGKey(6), "oph", K, D_BITS)
    shard_bytes = [os.path.getsize(p) for p in shard_paths]
    # TINY's train split shards as 3 x 68 examples: chunk_size 136 makes
    # chunk 0 cover shards 0-1 exactly
    fresh = [(np.asarray(s), np.asarray(y))
             for s, y in SignatureStream(shard_paths, fam, b=B,
                                         chunk_size=136)]
    assert len(fresh) == 2
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=136),
        cache_dir=str(tmp_path), max_cache_bytes=1)   # only chunk 0 fits
    for _ in cache:
        pass
    assert cache.stats.uncached_chunks == 1
    assert cache._tail_resume == (2, 0)               # tail = last shard
    raw_before = cache.stream.loader.stats.bytes_read
    replay = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    raw_replayed = cache.stream.loader.stats.bytes_read - raw_before
    assert raw_replayed == shard_bytes[2]             # prefix never re-read
    assert raw_replayed < sum(shard_bytes)
    assert len(replay) == len(fresh)
    for (s0, y0), (s1, y1) in zip(replay, fresh):
        np.testing.assert_array_equal(s0, s1)
        np.testing.assert_array_equal(y0, y1)


def test_cache_budget_replay_resumes_mid_shard(shard_paths, tmp_path):
    """Chunk boundaries that cut across a shard resume with an in-shard
    skip and stay bit-exact (chunk_size 48 vs 64-example shards)."""
    fam = make_family(jax.random.PRNGKey(2), "2u", K, D_BITS)
    fresh = [(np.asarray(s), np.asarray(y))
             for s, y in SignatureStream(shard_paths, fam, b=B,
                                         chunk_size=48)]
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=48),
        cache_dir=str(tmp_path), max_cache_bytes=1)
    for _ in cache:
        pass
    assert cache._tail_resume == (0, 48)              # mid-shard resume
    replay = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert len(replay) == len(fresh) > 2
    for (s0, y0), (s1, y1) in zip(replay, fresh):
        np.testing.assert_array_equal(s0, s1)
        np.testing.assert_array_equal(y0, y1)


def test_cache_ttl_drops_stale_shards_and_repopulates(shard_paths, tmp_path):
    """TTL eviction (mtime-based): stale shard files are removed on the
    next pass, the cache re-populates, and the output stays bit-exact."""
    import os

    fam = make_family(jax.random.PRNGKey(3), "oph", K, D_BITS)
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=str(tmp_path), ttl_s=3600.0)
    epoch0 = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert cache.populated and cache.stats.shards > 1
    # fresh shards: replay untouched
    replay = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert cache.populated and cache.ttl_dropped == 0
    # age one shard past the TTL (mtime injection)
    stale_path = cache.paths[1]
    old = os.path.getmtime(stale_path) - 7200.0
    os.utime(stale_path, (old, old))
    repop = [(np.asarray(s), np.asarray(y)) for s, y in cache]
    assert cache.ttl_dropped == 1
    assert cache.populated                       # pass re-populated it
    assert all(os.path.exists(p) for p in cache.paths)
    assert len(epoch0) == len(replay) == len(repop) > 1
    for (s0, y0), (s1, y1), (s2, y2) in zip(epoch0, replay, repop):
        np.testing.assert_array_equal(s0, s1)
        np.testing.assert_array_equal(s0, s2)
        np.testing.assert_array_equal(y0, y2)


def test_cache_ttl_sweeps_stale_leftovers_on_populate(shard_paths, tmp_path):
    """A shared cache_dir may hold sig_*.sig leftovers from an earlier
    process; populate removes the ones older than the TTL."""
    import os

    leftover = str(tmp_path / "sig_99999.sig")
    with open(leftover, "wb") as f:
        f.write(b"stale leftover")
    old = os.path.getmtime(leftover) - 7200.0
    os.utime(leftover, (old, old))
    fresh = str(tmp_path / "sig_88888.sig")
    with open(fresh, "wb") as f:
        f.write(b"fresh leftover")

    fam = make_family(jax.random.PRNGKey(4), "2u", K, D_BITS)
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=str(tmp_path), ttl_s=3600.0)
    for _ in cache:
        pass
    assert not os.path.exists(leftover)          # past the TTL: swept
    assert os.path.exists(fresh)                 # inside the TTL: kept
    assert cache.ttl_dropped == 1


def test_populate_crash_mid_write_leaves_no_partial_shard(
        shard_paths, tmp_path, monkeypatch):
    """A crash halfway through a cache-shard write must never publish a
    truncated sig_*.sig (writes go to a tmp name and os.replace over the
    final path only when complete) nor leak the tmp file or the dir lock."""
    import glob
    import os

    from repro.data import sigshard
    from repro.data.sigshard import read_sig_shard

    fam = make_family(jax.random.PRNGKey(5), "oph", K, D_BITS)
    cache_dir = str(tmp_path / "crashy")
    cache = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=cache_dir)
    real = sigshard._write_payload
    calls = []

    def crashing(f, words):
        calls.append(1)
        if len(calls) == 2:
            f.write(b"\x00\x01\x02")             # partial garbage, then die
            raise RuntimeError("simulated crash mid-write")
        return real(f, words)

    monkeypatch.setattr(sigshard, "_write_payload", crashing)
    with pytest.raises(RuntimeError, match="simulated crash"):
        for _ in cache:
            pass
    visible = sorted(glob.glob(os.path.join(cache_dir, "sig_*.sig")))
    assert len(visible) == 1                     # only the COMPLETE shard
    read_sig_shard(visible[0])                   # and it parses
    assert not glob.glob(os.path.join(cache_dir, "*.tmp.*"))
    assert not os.path.exists(os.path.join(cache_dir, ".lock"))

    # with the fault gone, a fresh cache over the same dir populates and
    # replays bit-exact -- the crash left nothing poisonous behind
    monkeypatch.undo()
    clean = SignatureCache(
        SignatureStream(shard_paths, fam, b=B, chunk_size=64),
        cache_dir=cache_dir)
    first = [np.asarray(getattr(s, "data", s)) for s, _ in clean]
    assert clean.populated and len(first) > 1
    replay = [np.asarray(getattr(s, "data", s)) for s, _ in clean]
    for a, b_ in zip(first, replay):
        np.testing.assert_array_equal(a, b_)
