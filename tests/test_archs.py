"""Per-architecture smoke tests: reduced config, one step, shapes + finite."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, cells_for, is_skipped
from repro.launch.steps import build_cell, init_inputs

CASES = [(a, c.name) for a in sorted(all_archs())
         for c in cells_for(a) if not is_skipped(a, c.name)]

# Cell smokes cost 2-15s of tracing each; the fast tier keeps one or two
# representative cells per architecture and `-m slow` runs the full grid.
# The whitelist picks the cheapest cells that still exercise each arch's
# step function (gatedgcn is covered at layer level by tests/test_gnn.py).
_FAST_CELLS = {("wide-deep", "serve_p99"), ("wide-deep", "train_batch")}


@pytest.mark.parametrize(
    "arch_id,cell_name",
    [pytest.param(a, c, id=f"{a}-{c}",
                  marks=[] if (a, c) in _FAST_CELLS else [pytest.mark.slow])
     for a, c in CASES])
def test_cell_smoke(arch_id, cell_name):
    key = jax.random.PRNGKey(0)
    prog = build_cell(arch_id, cell_name, smoke=True)
    params = prog.init_params(key)
    inputs = init_inputs(prog, key)
    if prog.opt_avals is not None:
        opt_state = prog.optimizer.init(params)
        p2, o2, loss = jax.jit(prog.step)(params, opt_state, inputs)
        assert jnp.isfinite(loss), f"loss not finite: {loss}"
        # params actually changed
        changed = any(
            not jnp.array_equal(a, b)
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(p2))
            if jnp.issubdtype(a.dtype, jnp.floating))
        assert changed, "train step did not update params"
    else:
        out = jax.jit(prog.step)(params, inputs)
        for leaf in jax.tree_util.tree_leaves(out):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_skipped_cells_documented():
    skipped = [(a, c) for a in sorted(all_archs()) for c in
               [cc.name for cc in cells_for(a)] if is_skipped(a, c)]
    # exactly the four pure-full-attention long_500k cells
    assert sorted(skipped) == [
        ("deepseek-7b", "long_500k"),
        ("deepseek-v3-671b", "long_500k"),
        ("mistral-large-123b", "long_500k"),
        ("yi-34b", "long_500k"),
    ]


def test_lm_param_counts_match_published():
    from repro.models.transformer import count_params, count_active_params
    from repro.configs import get_arch
    expect = {
        "deepseek-7b": (6.9e9, 0.1),
        "yi-34b": (34.4e9, 0.1),
        "mistral-large-123b": (122.6e9, 0.1),
        "deepseek-v3-671b": (671e9, 0.02),
        "llama4-scout-17b-a16e": (108e9, 0.1),
    }
    for arch, (n, tol) in expect.items():
        got = count_params(get_arch(arch).config)
        assert abs(got - n) / n < tol, (arch, got, n)
    active = count_active_params(get_arch("deepseek-v3-671b").config)
    assert abs(active - 37e9) / 37e9 < 0.1, active


@pytest.mark.slow
def test_decode_cache_is_updated():
    """serve_step writes K/V at pos-1 and returns tokens."""
    prog = build_cell("yi-34b", "decode_32k", smoke=True)
    key = jax.random.PRNGKey(1)
    params = prog.init_params(key)
    inputs = init_inputs(prog, key)
    toks, new_cache = jax.jit(prog.step)(params, inputs)
    assert toks.shape == inputs["tokens"].shape
    k_before = inputs["cache"]["layers"]["k"]
    k_after = new_cache["layers"]["k"]
    assert not jnp.array_equal(k_before, k_after)
    # only position pos-1 == 1 written
    diff = jnp.any(k_before != k_after, axis=(0, 1, 3, 4))
    assert bool(diff[1]) and not bool(jnp.any(diff[2:]))


@pytest.mark.slow
def test_moe_routes_to_multiple_experts():
    """Routing distribution check; EP-vs-dense parity (test_sharding_moe)
    covers MoE correctness in the fast tier."""
    from repro.models.moe import MoEConfig, init_moe_params, moe_ffn
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, n_shared=1)
    params = init_moe_params(jax.random.PRNGKey(0), 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    out = moe_ffn(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    # gradient flows
    g = jax.grad(lambda p: jnp.sum(moe_ffn(p, x, cfg) ** 2))(params)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert gn > 0


def test_chunked_local_attention_masks_cross_chunk():
    """llama4-style window: tokens must not attend across chunks."""
    from repro.models.attention import blockwise_attention
    import numpy as np
    B, S, H, hd = 1, 32, 2, 8
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd))
               for kk in jax.random.split(key, 3))
    full = blockwise_attention(q, k, v, window=0, blk_q=8, blk_kv=8)
    local = blockwise_attention(q, k, v, window=8, blk_q=8, blk_kv=8)
    # first token of chunk 2 (idx 8) attends only to itself under window=8
    # -> equals v[8] exactly
    np.testing.assert_allclose(np.asarray(local[0, 8]), np.asarray(v[0, 8]),
                               rtol=1e-4, atol=1e-5)
    # but differs from full attention
    assert not np.allclose(np.asarray(local[0, 8]), np.asarray(full[0, 8]))
