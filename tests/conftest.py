"""Test session setup: 8 host devices for sharding/shard_map tests.

NOTE: the multi-pod dry-run uses 512 devices but sets that itself in
repro.launch.dryrun (never globally); tests use a small count so smoke
tests and collective tests can coexist.
"""

import os

os.environ.setdefault("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_force_host_platform_device_count=8"
