"""Test session setup: 8 host devices for sharding/shard_map tests.

Must run before the first ``import jax`` anywhere in the test session --
XLA reads the flag once at backend init.  NOTE: the multi-pod dry-run
uses 512 devices but sets that itself in repro.launch.dryrun (never
globally); tests use a small count so smoke tests and collective tests
can coexist.

Tiers: the ``multidevice`` marker (registered in pyproject.toml, and
excluded from the default addopts selection next to ``slow``) guards
tests that only make sense with several devices -- the device-parallel
retrieval mesh regression suite.  They run as their own CI step with
``-m multidevice``; the ``host_devices`` fixture skips them gracefully
if the forced device count did not take (e.g. jax was already
initialised by a plugin).
"""

import os

import pytest

os.environ.setdefault("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_force_host_platform_device_count=8"


@pytest.fixture(autouse=True)
def _reset_obs():
    """Isolate the process-wide obs singletons across tests: counters
    accumulated by one test (e.g. retrace counts, serve totals) must not
    bleed into the next test's snapshot.  Lazy imports keep collection
    cheap for tests that never touch repro."""
    yield
    from repro.obs.metrics import get_registry
    from repro.obs.trace import get_tracer
    get_registry().reset()
    get_tracer().reset(enabled=False)


@pytest.fixture(scope="session")
def host_devices():
    """The forced 8-CpuDevice set; skips if the forcing didn't take."""
    import jax

    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip(f"needs 8 forced host devices, found {len(devs)} "
                    "(jax initialised before conftest set XLA_FLAGS?)")
    return devs
