"""GNN: segment-sum message passing vs dense-adjacency oracle + sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import (CSRGraph, GNNConfig, gatedgcn_layer,
                              gnn_forward, gnn_loss, init_gnn_params,
                              neighbor_sample, subgraph_sizes)


def _toy_graph(n=12, p=0.4, seed=0):
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < p
    np.fill_diagonal(adj, False)
    src, dst = np.nonzero(adj)
    return adj, jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)


def test_gatedgcn_layer_matches_dense_oracle():
    """segment_sum aggregation == explicit dense-adjacency computation."""
    adj, src, dst = _toy_graph()
    n, d = adj.shape[0], 8
    key = jax.random.PRNGKey(0)
    cfg = GNNConfig("t", 1, d, d, 2)
    params = init_gnn_params(cfg, key)
    lp = jax.tree_util.tree_map(lambda x: x[0], params["layers"])
    h = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    e = jax.random.normal(jax.random.PRNGKey(2), (src.shape[0], d))
    mask = jnp.ones((src.shape[0],))
    h_new, e_new = gatedgcn_layer(lp, h, e, src, dst, mask, n)

    # dense oracle
    from repro.models.layers import rms_norm
    hs, hd_ = np.asarray(h)[np.asarray(src)], np.asarray(h)[np.asarray(dst)]
    A, B, C, U, V = (np.asarray(lp[k]) for k in "ABCUV")
    e_np = hd_ @ A + hs @ B + np.asarray(e) @ C
    gate = 1 / (1 + np.exp(-e_np))
    gate_sum = np.zeros((n, d)); np.add.at(gate_sum, np.asarray(dst), gate)
    eta = gate / (gate_sum[np.asarray(dst)] + 1e-6)
    msg = eta * (hs @ V)
    agg = np.zeros((n, d)); np.add.at(agg, np.asarray(dst), msg)
    pre = np.asarray(h) @ U + agg
    want_h = np.asarray(h) + np.maximum(
        np.asarray(rms_norm(jnp.asarray(pre), lp["ln_h"])), 0)
    np.testing.assert_allclose(np.asarray(h_new), want_h, rtol=2e-4,
                               atol=2e-4)


@pytest.mark.slow
def test_edge_mask_blocks_messages():
    adj, src, dst = _toy_graph(seed=1)
    n, d = adj.shape[0], 4
    cfg = GNNConfig("t", 2, d, 6, 3)
    params = init_gnn_params(cfg, jax.random.PRNGKey(0))
    feats = jax.random.normal(jax.random.PRNGKey(1), (n, 6))
    batch = {"node_feats": feats,
             "edge_index": jnp.stack([src, dst]),
             "edge_mask": jnp.zeros((src.shape[0],)),
             "labels": jnp.zeros((n,), jnp.int32),
             "node_mask": jnp.ones((n,))}
    out_masked = gnn_forward(params, batch, cfg)
    # no edges at all == all edges masked
    batch2 = dict(batch, edge_index=jnp.zeros((2, src.shape[0]), jnp.int32))
    out_none = gnn_forward(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out_masked), np.asarray(out_none),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_neighbor_sampler_valid_and_static():
    rng = np.random.default_rng(2)
    n = 100
    degrees = rng.integers(1, 10, n)
    indptr = np.concatenate([[0], np.cumsum(degrees)])
    indices = rng.integers(0, n, indptr[-1])
    g = CSRGraph(indptr=jnp.asarray(indptr, jnp.int32),
                 indices=jnp.asarray(indices, jnp.int32))
    seeds = jnp.asarray(rng.choice(n, 16, replace=False), jnp.int32)
    fanouts = (4, 3)
    sub = neighbor_sample(jax.random.PRNGKey(0), g, seeds, fanouts)
    n_sub, e_sub = subgraph_sizes(16, fanouts)
    assert sub["nodes"].shape == (n_sub,)
    assert sub["edge_index"].shape == (2, e_sub)
    # every sampled edge's endpoints are valid local indices
    assert int(jnp.max(sub["edge_index"])) < n_sub
    # sampled neighbors really are neighbors in the CSR graph
    nodes = np.asarray(sub["nodes"])
    ei = np.asarray(sub["edge_index"])
    em = np.asarray(sub["edge_mask"])
    for j in range(min(50, ei.shape[1])):
        if not em[j]:
            continue
        s_glob, d_glob = nodes[ei[0, j]], nodes[ei[1, j]]
        nbrs = indices[indptr[d_glob]:indptr[d_glob + 1]]
        assert s_glob in nbrs, (s_glob, d_glob)


@pytest.mark.slow
def test_graph_readout_shapes():
    cfg = GNNConfig("t", 2, 8, 5, 3, readout="graph")
    params = init_gnn_params(cfg, jax.random.PRNGKey(0))
    n_graphs, per = 4, 6
    n = n_graphs * per
    batch = {
        "node_feats": jax.random.normal(jax.random.PRNGKey(1), (n, 5)),
        "edge_index": jnp.zeros((2, 16), jnp.int32),
        "edge_mask": jnp.ones((16,)),
        "labels": jnp.zeros((n_graphs,), jnp.int32),
        "node_mask": jnp.ones((n,)),
        "graph_ids": jnp.repeat(jnp.arange(n_graphs, dtype=jnp.int32), per),
    }
    logits = gnn_forward(params, batch, cfg)
    assert logits.shape == (n_graphs, 3)
    loss = gnn_loss(params, batch, cfg)
    assert jnp.isfinite(loss)
