"""Optimizers, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, apply_updates, constant,
                         compressed_psum_int8, dequantize_int8, inverse_time,
                         quantize_int8, sgd, topk_decompress,
                         topk_error_feedback, warmup_cosine)


def _quadratic():
    A = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)
    A = A @ A.T + 0.5 * jnp.eye(8)
    b = jnp.ones((8,))

    def loss(params):
        x = params["x"]
        return 0.5 * x @ A @ x - b @ x + jnp.sum(params["y"]["z"] ** 2)

    params = {"x": jnp.ones((8,)) * 3.0, "y": {"z": jnp.ones((4, 4))}}
    return loss, params


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.02, momentum=0.9),
    lambda: adamw(constant(0.1)),
    lambda: adafactor(constant(0.5)),
])
def test_optimizers_decrease_quadratic(make_opt):
    loss, params = _quadratic()
    opt = make_opt()
    state = opt.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s: _opt_step(opt, loss, p, s))
    for _ in range(120):
        params, state = step(params, state)
    assert float(loss(params)) < 0.2 * l0


def _opt_step(opt, loss, params, state):
    g = jax.grad(loss)(params)
    u, state = opt.update(g, state, params)
    return apply_updates(params, u), state


def test_adafactor_state_is_factored():
    _, params = _quadratic()
    opt = adafactor(constant(0.1))
    state = opt.init(params)
    # matrix param (4,4) stores vr (4,) and vc (4,), not (4,4)
    assert state["v"]["y"]["z"]["vr"].shape == (4,)
    assert state["v"]["y"]["z"]["vc"].shape == (4,)
    # vector param keeps full second moment
    assert state["v"]["x"]["v"].shape == (8,)


def test_schedules():
    assert float(constant(0.1)(jnp.int32(5))) == pytest.approx(0.1)
    it = inverse_time(1.0, 0.1)
    assert float(it(jnp.int32(0))) == pytest.approx(1.0)
    assert float(it(jnp.int32(90))) == pytest.approx(1.0 / 10.0)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.int32(4))) == pytest.approx(0.5)   # (c+1)/warmup
    assert float(wc(jnp.int32(0))) > 0.0                   # step 0 trains
    assert float(wc(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


def test_int8_quantization_error_bound():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(1000,)), jnp.float32)
    q, scale = quantize_int8(g, jax.random.PRNGKey(0))
    back = dequantize_int8(q, scale)
    # error bounded by one quantization step
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) / 127.0 + 1e-6


def test_int8_stochastic_rounding_unbiased():
    g = jnp.full((20000,), 0.3337)
    q, scale = quantize_int8(g, jax.random.PRNGKey(1), scale=jnp.float32(1.0))
    mean = float(jnp.mean(dequantize_int8(q, scale)))
    assert abs(mean - 0.3337) < 5e-4


def test_compressed_psum_matches_mean():
    devs = jax.devices()
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    mesh = Mesh(np.array(devs[:1]), ("dp",))
    g = jnp.asarray(np.random.default_rng(2).normal(size=(64,)), jnp.float32)

    def f(g):
        return compressed_psum_int8(g, jax.random.PRNGKey(0), "dp")

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(), out_specs=P()))(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.1)


def test_make_compressed_allreduce_helper():
    from jax.sharding import Mesh
    from repro.optim.compression import make_compressed_allreduce
    mesh = Mesh(np.array(jax.devices()[:2]).reshape(2), ("dp",))
    g = jnp.asarray(np.random.default_rng(3).normal(size=(32,)), jnp.float32)
    f = jax.jit(make_compressed_allreduce(mesh))
    out = f(g, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.1)


def test_topk_error_feedback_accumulates():
    g = jnp.asarray([1.0, -0.5, 0.25, 0.1])
    residual = jnp.zeros((4,))
    vals, idx, residual, sent = topk_error_feedback(g, residual, k=1)
    assert float(sent[0]) == pytest.approx(1.0)         # largest kept
    assert float(residual[1]) == pytest.approx(-0.5)    # rest carried
    # second step: residual re-enters; -0.5-0.5 = -1.0 now dominates
    vals, idx, residual, sent = topk_error_feedback(g * 0 - jnp.asarray(
        [0.0, 0.5, 0.0, 0.0]), residual, k=1)
    # corrected g[1] = -0.5 + (-0.5)... transmitted eventually
    dense = topk_decompress(vals, idx, (4,))
    assert np.count_nonzero(np.asarray(dense)) == 1
