"""LSH dedup application + distributed preprocessing driver."""

import numpy as np
import jax
import pytest

from repro.core import Hash2U, lowest_bits, minhash_signatures
from repro.core.bbit import unpack_signatures
from repro.core.lsh import (LSHConfig, band_keys, candidate_pairs, dedup,
                            match_probability)
from repro.data import word_pair_sets
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards, read_signature_shard
from repro.data.sparse import from_lists
from repro.data.synthetic import TINY


def _docs_with_duplicates(D=2**18, seed=0):
    """6 docs: (0,1) near-dups R~0.9, (2,3) R~0.5, others unrelated."""
    rng = np.random.default_rng(seed)
    s0, s1 = word_pair_sets(D, 800, 820, 0.9, seed=1)
    s2, s3 = word_pair_sets(D, 500, 520, 0.5, seed=2)
    s4 = np.sort(rng.choice(D, 600, replace=False))
    s5 = np.sort(rng.choice(D, 700, replace=False))
    return [s0, s1, s2, s3, s4, s5], D


def test_lsh_finds_near_duplicates():
    docs, D = _docs_with_duplicates()
    cfg = LSHConfig(n_bands=16, rows_per_band=4, b=8)
    fam = Hash2U.create(jax.random.PRNGKey(0), cfg.k, 18)
    batch = from_lists(docs)
    sig = lowest_bits(minhash_signatures(batch.indices, batch.mask, fam),
                      cfg.b)
    found = dedup(sig, [len(d) for d in docs], D, cfg, threshold=0.8)
    pairs = [(i, j) for i, j, _ in found]
    assert (0, 1) in pairs, found
    # unrelated docs never pass verification
    assert all({i, j} <= {0, 1, 2, 3} for i, j in pairs), found


def test_lsh_s_curve_is_monotone_and_selective():
    cfg = LSHConfig(n_bands=16, rows_per_band=4, b=8)
    p_low = match_probability(0.2, 800, 800, 2**18, cfg)
    p_mid = match_probability(0.6, 800, 800, 2**18, cfg)
    p_high = match_probability(0.95, 800, 800, 2**18, cfg)
    assert p_low < p_mid < p_high
    assert p_high > 0.95 and p_low < 0.5


def test_band_keys_roundtrip_and_candidates():
    cfg = LSHConfig(n_bands=4, rows_per_band=3, b=4)
    rng = np.random.default_rng(1)
    sig = jax.numpy.asarray(rng.integers(0, 16, (5, cfg.k)),
                            jax.numpy.uint32)
    keys = np.asarray(band_keys(sig, cfg))
    assert keys.shape == (5, 4)
    # identical signatures -> candidates in every band
    sig2 = sig.at[1].set(sig[0])
    keys2 = np.asarray(band_keys(sig2, cfg))
    assert (0, 1) in candidate_pairs(keys2)


def test_preprocess_pipeline_roundtrip(tmp_path):
    paths = make_sharded_dataset(TINY, str(tmp_path / "raw"), n_shards=2,
                                 n=120)
    fam = Hash2U.create(jax.random.PRNGKey(3), 64, 16)
    out = str(tmp_path / "sig")
    stats = preprocess_shards(paths, out, fam, b=8, chunk_size=48,
                              loader_kwargs={"lane_multiple": 8})
    assert stats.examples == 96          # 80% train split of 120
    assert stats.kernel_s > 0 and stats.load_s > 0 and stats.store_s > 0
    assert stats.reduction() > 2.0       # the paper's size reduction

    # signatures on disk decode to exactly the direct computation
    import os
    shard0 = sorted(os.listdir(out))[0]
    packed, labels, k, b = read_signature_shard(os.path.join(out, shard0))
    assert (k, b) == (64, 8)
    from repro.data.pipeline import ChunkedLoader
    chunk = next(iter(ChunkedLoader(paths, chunk_size=48,
                                    lane_multiple=8)))
    direct = lowest_bits(
        minhash_signatures(chunk.indices, chunk.mask, fam), 8)
    got = unpack_signatures(jax.numpy.asarray(packed), 8, 64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(direct))


def test_preprocess_rejects_permutations(tmp_path):
    from repro.core import PermutationFamily
    fam = PermutationFamily.create(jax.random.PRNGKey(0), 8, 2**10)
    with pytest.raises(TypeError):
        preprocess_shards([], str(tmp_path), fam)
