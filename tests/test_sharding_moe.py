"""Sharding rules, EP MoE vs dense oracle, fused optimizer parity,
HLO collective parsing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (MoEConfig, _moe_ffn_dense, ep_layout,
                              init_moe_params, moe_ffn)
from repro.optim import adafactor, constant
from repro.optim.base import apply_updates
from repro.optim.optimizers import adafactor_fused
from repro.roofline.hlo import collective_bytes, shape_bytes
from repro.sharding.rules import constrain, set_mesh, spec


@pytest.fixture(scope="module")
def mesh8():
    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip("needs >= 8 devices (run under "
                    "--xla_force_host_platform_device_count)")
    return jax.make_mesh((2, 4), ("data", "model"))


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", "model") is x


def test_spec_resolution(mesh8):
    def flat(entry):
        if entry is None:
            return ()
        return (entry,) if isinstance(entry, str) else tuple(entry)

    with set_mesh(mesh8):
        s = tuple(spec("batch", None, "model"))
        assert flat(s[0]) == ("data",)
        assert s[1] is None and flat(s[2]) == ("model",)
        s_all = tuple(spec("all"))
        assert flat(s_all[0]) == ("data", "model")


def test_constrain_drops_indivisible(mesh8):
    with set_mesh(mesh8):
        x = jnp.ones((6, 8))      # 6 % 2 == 0 but 6 % ... model=4: 8%4==0
        y = constrain(x, "model", None)   # 6 % 4 != 0 -> dropped
        assert y.shape == x.shape  # compiles as replicated, no error


def test_ep_layout():
    class M:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}
    ep, ffn, rest = ep_layout(M, 8)
    assert ep == ("model", "data") and ffn == () and rest == ()
    ep, ffn, rest = ep_layout(M, 4)
    assert ep == ("model",) and ffn == ("data",) and rest == ("data",)


@pytest.mark.parametrize(
    "T", [64, pytest.param(6, marks=pytest.mark.slow)])  # a2a; psum fallback
def test_moe_ep_matches_dense(mesh8, T):
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                    router="sigmoid", capacity_factor=8.0)
    params = init_moe_params(jax.random.PRNGKey(0), 64, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, 64))
    dense = _moe_ffn_dense(params, x, cfg)
    with set_mesh(mesh8):
        ep = jax.jit(lambda p, xx: moe_ffn(p, xx, cfg))(params, x)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ep),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_moe_ep_gradients(mesh8):
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, n_shared=0,
                    router="softmax", capacity_factor=4.0)
    params = init_moe_params(jax.random.PRNGKey(2), 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 32))
    with set_mesh(mesh8):
        g = jax.jit(jax.grad(
            lambda p: jnp.sum(moe_ffn(p, x, cfg) ** 2)))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    total = sum(float(jnp.sum(jnp.abs(l)))
                for l in jax.tree_util.tree_leaves(g))
    assert total > 0


def test_adafactor_fused_matches_unfused():
    """Fused (apply-included, layer-scanned) == plain adafactor + apply."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (12, 6, 8)),
              "b": jnp.ones((8,))}
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape) * 0.01,
        params)
    # huge clip threshold: per-slice vs whole-tensor update clipping is the
    # one intentional semantic difference; disable it to compare the math
    plain = adafactor(constant(0.1), momentum=None, clip_threshold=1e9)
    fused = adafactor_fused(constant(0.1), momentum=None,
                            scan_min_leading=4, clip_threshold=1e9)
    s1, s2 = plain.init(params), fused.init(params)
    u, s1 = plain.update(grads, s1, params)
    p_plain = apply_updates(params, u)
    p_fused, s2 = fused.update(grads, s2, params)
    for a, b in zip(jax.tree_util.tree_leaves(p_plain),
                    jax.tree_util.tree_leaves(p_fused)):
        # per-slice update clipping can differ from whole-tensor clipping
        # only when the clip is active; with tiny grads it is not
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-5)


def test_hlo_collective_parser():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64,64]{1,0} all-gather(bf16[8,64]{1,0} %y), dimensions={0}
  %dot = f32[4,4]{1,0} dot(f32[4,4]{1,0} %a, f32[4,4]{1,0} %b)
"""
    total, breakdown = collective_bytes(hlo)
    assert breakdown["all-reduce"] == 128 * 256 * 4
    assert breakdown["all-gather"] == 64 * 64 * 2      # max(result, operand)
    assert total == breakdown["all-reduce"] + breakdown["all-gather"]
    assert shape_bytes("bf16", "2,3") == 12


def test_param_specs_cover_all_archs():
    """Every arch's param tree gets a spec tree with matching structure."""
    from repro.configs import all_archs
    from repro.launch.steps import build_cell
    from repro.configs import cells_for, is_skipped
    for arch_id in sorted(all_archs()):
        cell = next(c for c in cells_for(arch_id)
                    if not is_skipped(arch_id, c.name))
        prog = build_cell(arch_id, cell.name, smoke=True)
        n_p = len(jax.tree_util.tree_leaves(prog.param_avals))
        n_s = len(jax.tree_util.tree_leaves(
            prog.param_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        assert n_p == n_s, arch_id
