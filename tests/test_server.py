"""Continuous-batching SearchServer + lock-file coordination + live
appends under readers: the PR-6 serving-path promises.

  * micro-batched server results bit-identical to direct ``search()``
    (and the batch triggers: full, aged, deadline, drain),
  * ``FileLock`` mutual exclusion, reentrancy, timeout, stale break,
  * flush racing ``ShardedIndex.append``: every result consistent with
    the pre- OR post-append corpus, never a torn mix; a second router
    picks the append up via the manifest generation,
  * ``--smoke``/``--no-smoke`` actually both parse (the old store_true
    default=True could never be disabled),
  * ``ZipfianTraffic`` determinism and shape.
"""

import glob
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.oph import OPH
from repro.data.lockfile import FileLock, LockTimeout
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import (IndexSearcher, build_index, build_sharded,
                         choose_band_config, load_index, load_sharded)
from repro.launch.serve import build_parser
from repro.launch.server import (RequestShed, SearchServer, ServerStats,
                                 ZipfianTraffic)

K, S, B = 128, 16, 8


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """Synthetic corpus as .sig shards + one single-index searcher."""
    tmp = str(tmp_path_factory.mktemp("server_corpus"))
    spec = DatasetSpec("servertest", n=260, D=1 << S, avg_nnz=48,
                       n_prototypes=6, overlap=0.8, seed=4)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=4)
    fam = OPH.create(jax.random.PRNGKey(1), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    assert len(sig_paths) >= 4
    cfg = choose_band_config(K, B, threshold=0.5)
    idx_path = os.path.join(tmp, "single.idx")
    build_index(sig_paths, idx_path, cfg)
    return tmp, sig_paths, cfg, idx_path


@pytest.fixture(scope="module")
def searcher(corpus):
    _, _, _, idx_path = corpus
    return IndexSearcher(load_index(idx_path), backend="interpret",
                         corpus_block=128)


# ---------------------------------------------------------------------------
# FileLock
# ---------------------------------------------------------------------------

def test_filelock_mutual_exclusion_and_timeout(tmp_path):
    path = str(tmp_path / "x.lock")
    a = FileLock(path)
    b = FileLock(path, timeout_s=0.05, poll_s=0.005)
    with a:
        assert a.held and os.path.exists(path)
        with pytest.raises(LockTimeout):
            b.acquire()
    assert not os.path.exists(path)              # released -> removed
    with b:                                      # free again
        assert b.held


def test_filelock_reentrant(tmp_path):
    lock = FileLock(str(tmp_path / "r.lock"))
    with lock:
        with lock:                               # same instance re-enters
            assert lock.held
        assert lock.held                         # inner exit keeps it
    assert not lock.held


def test_filelock_breaks_stale(tmp_path):
    path = str(tmp_path / "dead.lock")
    with open(path, "w") as f:
        f.write("999999 0")                      # a crashed holder
    old = time.time() - 3600
    os.utime(path, (old, old))
    lock = FileLock(path, timeout_s=1.0, poll_s=0.01, stale_s=60.0)
    with lock:                                   # broke the stale file
        assert lock.held
    # without stale breaking the same file times out
    with open(path, "w") as f:
        f.write("999999 0")
    os.utime(path, (old, old))
    with pytest.raises(LockTimeout):
        FileLock(path, timeout_s=0.05, poll_s=0.005).acquire()


def test_filelock_released_on_generator_abandon(tmp_path, corpus):
    """Abandoning a SignatureCache populate pass mid-epoch must release
    the cache dir's lock (generator close runs the with-block exit)."""
    from repro.data.pipeline import SignatureStream
    from repro.train.online import SignatureCache, make_family
    fam = make_family(jax.random.PRNGKey(0), "oph", K, S)
    raw = sorted(glob.glob(os.path.join(corpus[0], "raw", "*")))
    cache_dir = str(tmp_path / "shared")
    cache = SignatureCache(SignatureStream(raw, fam, b=B, chunk_size=64),
                           cache_dir=cache_dir)
    it = iter(cache)
    next(it)                                     # lock held mid-pass
    assert os.path.exists(os.path.join(cache_dir, ".lock"))
    it.close()
    assert not os.path.exists(os.path.join(cache_dir, ".lock"))
    # a second trainer sharing the dir can now populate immediately
    other = SignatureCache(SignatureStream(raw, fam, b=B, chunk_size=64),
                           cache_dir=cache_dir, lock_timeout_s=1.0)
    assert len(list(other)) > 0 and other.populated


# ---------------------------------------------------------------------------
# SearchServer
# ---------------------------------------------------------------------------

def test_server_bit_identical_to_direct_search(searcher):
    """Micro-batched results == direct search(), row for row."""
    n = searcher.index.n
    picks = [0, 3, n // 2, n - 1, 7, n // 3]
    rows = [np.asarray(searcher.index.words_host[i]) for i in picks]
    direct = searcher.search(np.stack(rows), 5, mode="exact")
    with SearchServer(searcher, max_batch=4, max_delay_s=0.01,
                      topk=5) as srv:
        handles = [srv.submit(r) for r in rows]
        results = [h.result(timeout=60.0) for h in handles]
    for j, res in enumerate(results):
        assert np.array_equal(res.indices[0], direct.indices[j])
        assert np.array_equal(res.scores[0], direct.scores[j])
    assert srv.stats.requests == len(picks)
    assert srv.stats.batches >= 2                # max_batch=4 over 6 reqs


def test_server_full_batch_trigger(searcher):
    """With a huge delay window, only a full queue can flush."""
    rows = [np.asarray(searcher.index.words_host[i]) for i in range(4)]
    with SearchServer(searcher, max_batch=2, max_delay_s=30.0,
                      topk=3) as srv:
        handles = [srv.submit(r) for r in rows]
        t0 = time.monotonic()
        for h in handles:
            h.result(timeout=60.0)
        assert time.monotonic() - t0 < 25.0      # did not wait out the delay
    assert srv.stats.flush_full >= 1
    assert srv.stats.flush_aged == 0


def test_server_aged_trigger_flushes_partial_batch(searcher):
    """A lone request flushes after max_delay_s, not never."""
    row = np.asarray(searcher.index.words_host[1])
    with SearchServer(searcher, max_batch=64, max_delay_s=0.05,
                      topk=3) as srv:
        h = srv.submit(row)
        h.result(timeout=60.0)
    assert srv.stats.flush_aged == 1
    assert srv.stats.flush_full == 0
    assert h.queue_wait_s >= 0.04                # sat out the delay window


def test_server_deadline_trigger(searcher):
    """An explicit deadline flushes before the aging window would."""
    row = np.asarray(searcher.index.words_host[2])
    with SearchServer(searcher, max_batch=64, max_delay_s=30.0,
                      topk=3) as srv:
        t0 = time.monotonic()
        h = srv.submit(row, deadline_s=0.25)
        h.result(timeout=60.0)
        assert time.monotonic() - t0 < 25.0
    assert srv.stats.flush_deadline == 1


def test_server_drains_on_stop(searcher):
    """stop() flushes whatever is queued instead of dropping it."""
    rows = [np.asarray(searcher.index.words_host[i]) for i in (1, 2, 3)]
    srv = SearchServer(searcher, max_batch=64, max_delay_s=30.0,
                       topk=3).start()
    handles = [srv.submit(r) for r in rows]
    srv.stop()
    for h in handles:
        assert h.done()
        assert h.result(timeout=0).indices.shape == (1, 3)
    assert srv.stats.flush_drain >= 1
    with pytest.raises(RuntimeError):
        srv.submit(rows[0])                      # stopped server rejects


def test_server_bad_query_fails_only_itself(searcher):
    """A malformed row errors its own handle; co-batched queries still
    get bit-identical results."""
    good = np.asarray(searcher.index.words_host[5])
    direct = searcher.search(good[None, :], 3, mode="exact")
    with SearchServer(searcher, max_batch=2, max_delay_s=30.0,
                      topk=3) as srv:
        h_bad = srv.submit(np.zeros(3, np.uint32))   # wrong word count
        h_good = srv.submit(good)
        res = h_good.result(timeout=60.0)
        with pytest.raises(ValueError):
            h_bad.result(timeout=60.0)
    assert np.array_equal(res.indices, direct.indices)
    assert np.array_equal(res.scores, direct.scores)
    assert srv.stats.errors == 1


def test_server_requires_start():
    with pytest.raises(RuntimeError, match="not started"):
        SearchServer(object()).submit(np.zeros(1))


def test_server_stats_snapshot(searcher):
    rows = [np.asarray(searcher.index.words_host[i]) for i in range(3)]
    with SearchServer(searcher, max_batch=3, max_delay_s=0.01,
                      topk=3) as srv:
        for h in [srv.submit(r) for r in rows]:
            h.result(timeout=60.0)
    snap = srv.stats.snapshot()
    assert snap["requests"] == 3 and snap["errors"] == 0
    for key in ("latency_p50_ms", "latency_p99_ms", "queue_wait_p50_ms",
                "flush_p50_ms", "mean_batch"):
        assert np.isfinite(snap[key]), key
    assert snap["latency_p99_ms"] >= snap["latency_p50_ms"]
    assert len(srv.stats.queue_wait_s) == 3      # one sample per request


def test_server_stats_reservoir_bounded():
    stats = ServerStats(window=4)
    for i in range(10):
        stats.latency_s.append(float(i))
    assert list(stats.latency_s) == [6.0, 7.0, 8.0, 9.0]


# ---------------------------------------------------------------------------
# Multi-worker dispatch + admission control
# ---------------------------------------------------------------------------

def test_server_multiworker_bit_identical(searcher):
    """Four dispatch workers draining one queue: every request's row is
    still bit-identical to direct search(), no matter which worker's
    flush served it, and the per-worker histograms account for every
    batch."""
    n = searcher.index.n
    rng = np.random.default_rng(42)
    picks = rng.integers(0, n, size=24)
    rows = [np.asarray(searcher.index.words_host[i]) for i in picks]
    direct = searcher.search(np.stack(rows), 5, mode="exact")
    with SearchServer(searcher, max_batch=4, max_delay_s=0.005,
                      topk=5, num_workers=4) as srv:
        handles = [srv.submit(r) for r in rows]
        results = [h.result(timeout=60.0) for h in handles]
    for j, res in enumerate(results):
        assert np.array_equal(res.indices[0], direct.indices[j])
        assert np.array_equal(res.scores[0], direct.scores[j])
    snap = srv.stats.snapshot()
    assert snap["workers"] == 4
    assert snap["requests"] == len(rows) and snap["errors"] == 0
    assert sum(snap["worker_flushes"]) == snap["batches"]
    assert len(snap["worker_occupancy"]) == 4
    assert all(h.outcome == "served" for h in handles)


class _SlowSearcher:
    """Wraps a real searcher so every flush costs a fixed wall-clock
    delay -- a deterministic overload lever for the admission tests."""

    def __init__(self, inner, delay_s):
        self.inner = inner
        self.delay_s = delay_s

    @property
    def spec(self):
        return self.inner.spec

    def search(self, queries, topk=10, *, mode="exact", query_sizes=None):
        time.sleep(self.delay_s)
        return self.inner.search(queries, topk, mode=mode,
                                 query_sizes=query_sizes)


def test_server_overload_sheds_and_never_deadlocks(searcher):
    """Offered load >> capacity with a bounded queue: shed-oldest drops
    traffic instead of blowing the budget, every handle resolves (no
    deadlock), and the requests that WERE served met their deadline."""
    slow = _SlowSearcher(searcher, 0.05)
    rows = [np.asarray(searcher.index.words_host[i % searcher.index.n])
            for i in range(60)]
    with SearchServer(slow, max_batch=4, max_delay_s=0.002, topk=3,
                      admission="shed-oldest", max_queue=8) as srv:
        handles = [srv.submit(r, deadline_s=5.0) for r in rows]
        for h in handles:
            if h.outcome != "shed":
                h.result(timeout=60.0)
    assert all(h.done() for h in handles)            # nothing stranded
    stats = srv.stats
    assert stats.shed > 0                            # overload really shed
    assert stats.requests + stats.shed == len(rows)  # full accounting
    assert stats.deadline_misses == 0                # survivors on budget
    shed_handles = [h for h in handles if h.outcome == "shed"]
    assert len(shed_handles) == stats.shed
    with pytest.raises(RequestShed):
        shed_handles[0].result(timeout=0)
    snap = stats.snapshot()
    assert snap["shed_rate"] == pytest.approx(
        stats.shed / len(rows))


def test_server_admission_reject_is_immediate(searcher):
    """reject resolves the arriving request at submit time -- the
    caller learns within the submit call, not after a queue wait."""
    slow = _SlowSearcher(searcher, 0.05)
    rows = [np.asarray(searcher.index.words_host[i % searcher.index.n])
            for i in range(30)]
    with SearchServer(slow, max_batch=4, max_delay_s=0.002, topk=3,
                      admission="reject", max_queue=4) as srv:
        handles = [srv.submit(r, deadline_s=5.0) for r in rows]
        rejected = [h for h in handles if h.done() and h.outcome == "shed"]
        assert rejected                              # rejected at admission
        for h in handles:
            if h.outcome != "shed":
                h.result(timeout=60.0)
    assert srv.stats.shed == len([h for h in handles
                                  if h.outcome == "shed"])
    assert srv.stats.requests + srv.stats.shed == len(rows)
    assert srv.stats.deadline_misses == 0


def test_server_degrade_to_lsh(searcher):
    """Under a budget no exact flush can meet, degrade-to-lsh serves
    every request -- nothing shed -- through the LSH path, bit-identical
    to a direct mode='lsh' search."""
    n = searcher.index.n
    rows = [np.asarray(searcher.index.words_host[i])
            for i in (0, 3, n // 2, n - 1)]
    direct = searcher.search(np.stack(rows), 5, mode="lsh")
    with SearchServer(searcher, max_batch=4, max_delay_s=0.01, topk=5,
                      admission="degrade-to-lsh",
                      deadline_budget_s=1e-6) as srv:   # unmeetable budget
        handles = [srv.submit(r) for r in rows]
        results = [h.result(timeout=60.0) for h in handles]
    assert all(h.outcome == "degraded" for h in handles)
    for j, res in enumerate(results):
        assert np.array_equal(res.indices[0], direct.indices[j])
        assert np.array_equal(res.scores[0], direct.scores[j])
    assert srv.stats.shed == 0
    assert srv.stats.degraded == len(rows)
    assert srv.stats.snapshot()["degraded_rate"] == 1.0


def test_server_admission_validation(searcher):
    with pytest.raises(ValueError, match="admission"):
        SearchServer(searcher, admission="drop-everything")
    with pytest.raises(ValueError, match="degrade-to-lsh"):
        SearchServer(searcher, admission="degrade-to-lsh", mode="lsh")
    with pytest.raises(ValueError, match="max_queue"):
        SearchServer(searcher, admission="reject", max_queue=0)
    with pytest.raises(ValueError, match="num_workers"):
        SearchServer(searcher, num_workers=0)


def test_server_stats_concurrent_snapshot(searcher):
    """Seeded multi-thread submit storm while snapshot() runs hot:
    every snapshot is computed from a consistent copy (np.percentile
    over a mutating deque raises RuntimeError -- this pins the lock-
    copy), and the final counters account for every request."""
    n = searcher.index.n
    rng = np.random.default_rng(7)
    per_thread = 25
    picks = rng.integers(0, n, size=(4, per_thread))
    snap_errors, submit_errors = [], []
    with SearchServer(searcher, max_batch=8, max_delay_s=0.001,
                      topk=3, num_workers=2) as srv:
        def storm(t):
            try:
                hs = [srv.submit(
                    np.asarray(searcher.index.words_host[i]))
                    for i in picks[t]]
                for h in hs:
                    h.result(timeout=60.0)
            except Exception as e:               # pragma: no cover
                submit_errors.append(e)

        threads = [threading.Thread(target=storm, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        seen = 0
        while any(t.is_alive() for t in threads):
            try:
                snap = srv.stats.snapshot()
            except RuntimeError as e:            # pragma: no cover
                snap_errors.append(e)
                break
            assert snap["requests"] >= seen      # monotone, never torn
            seen = snap["requests"]
        for t in threads:
            t.join()
    assert not submit_errors and not snap_errors
    snap = srv.stats.snapshot()
    assert snap["requests"] == 4 * per_thread
    assert snap["errors"] == 0
    assert sum(snap["worker_flushes"]) == snap["batches"]


def test_server_worker_survives_flush_crash(searcher):
    """A worker whose flush blows up mid-storm is restarted: the dead
    batch's handles resolve as errors (never strand), later requests
    are served normally, and the restart is counted."""
    n = searcher.index.n
    rows = [np.asarray(searcher.index.words_host[i % n])
            for i in range(24)]
    with SearchServer(searcher, max_batch=4, max_delay_s=0.002,
                      topk=3, num_workers=2) as srv:
        real = srv._flush_batch
        crashes = [2]

        def flaky(batch, trigger, wi, handle):
            if crashes[0] > 0:
                crashes[0] -= 1
                raise RuntimeError("injected flush crash")
            return real(batch, trigger, wi, handle)

        srv._flush_batch = flaky
        handles = [srv.submit(r) for r in rows]
        outcomes = []
        for h in handles:
            try:
                res = h.result(timeout=60.0)
                assert res.indices.shape == (1, 3)   # never torn
                outcomes.append("served")
            except RuntimeError as e:
                assert "injected flush crash" in str(e)
                outcomes.append("error")
    assert all(h.done() for h in handles)            # nothing stranded
    assert crashes[0] == 0                           # both crashes fired
    assert outcomes.count("error") >= 1
    assert outcomes.count("served") >= 1             # server kept serving
    snap = srv.stats.snapshot()
    assert snap["worker_restarts"] == 2
    # full accounting: every row either served (counted) or errored
    assert snap["requests"] == outcomes.count("served")
    assert snap["requests"] + outcomes.count("error") == len(rows)
    assert srv.stats.errors >= 2


def test_zipfian_traffic_identical_across_worker_counts(searcher):
    """The load model is independent of the serving side: the same seed
    replays the same query ids and arrival times no matter how many
    workers serve it, and both servers return bit-identical results."""
    m = 16
    ids = {}
    results = {}
    for workers in (1, 3):
        traffic = ZipfianTraffic(searcher.index.n, alpha=1.1, seed=13)
        ids[workers] = traffic.ids(m)
        offs = traffic.arrival_offsets(m, rate_qps=5000.0)
        with SearchServer(searcher, max_batch=4, max_delay_s=0.002,
                          topk=5, num_workers=workers) as srv:
            handles = [srv.submit(
                np.asarray(searcher.index.words_host[i]))
                for i in ids[workers]]
            results[workers] = [h.result(timeout=60.0) for h in handles]
        ids[f"offs{workers}"] = offs
    np.testing.assert_array_equal(ids[1], ids[3])
    np.testing.assert_array_equal(ids["offs1"], ids["offs3"])
    for a, b in zip(results[1], results[3]):
        assert np.array_equal(a.indices, b.indices)
        assert np.array_equal(a.scores, b.scores)


# ---------------------------------------------------------------------------
# Live appends under readers
# ---------------------------------------------------------------------------

@pytest.fixture()
def growing_router(corpus, tmp_path):
    tmp, sig_paths, cfg, _ = corpus
    shard_dir = str(tmp_path / "growing")
    build_sharded(sig_paths[:3], shard_dir, cfg, n_shards=2)
    router = load_sharded(shard_dir, backend="interpret", corpus_block=64)
    return router, sig_paths[3:]


def test_search_racing_append_never_torn(growing_router):
    """Concurrent search() calls during append() return results equal to
    the pre-append OR the post-append corpus -- never a torn mix."""
    router, extra = growing_router
    n0 = router.n
    q = np.ascontiguousarray(
        router.searchers[0].index.words_host[[0, 3, 9, 17]])
    pre = router.search(q, 5, mode="exact")
    results, errors = [], []

    def reader():
        try:
            for _ in range(10):
                results.append(router.search(q, 5, mode="exact"))
        except Exception as e:               # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.02)
    router.append(extra)
    t.join()
    assert not errors
    assert router.n > n0
    post = router.search(q, 5, mode="exact")
    assert not (np.array_equal(pre.indices, post.indices)
                and np.array_equal(pre.scores, post.scores))
    for res in results:
        matches_pre = (np.array_equal(res.indices, pre.indices)
                       and np.array_equal(res.scores, pre.scores))
        matches_post = (np.array_equal(res.indices, post.indices)
                        and np.array_equal(res.scores, post.scores))
        assert matches_pre or matches_post


def test_server_flush_picks_up_append_via_refresh(growing_router):
    """Flushes before the append serve the old corpus, flushes after it
    serve the grown corpus -- the server's per-flush refresh() is the
    reader side of the generation-versioned manifest."""
    router, extra = growing_router
    q_rows = [np.asarray(router.searchers[0].index.words_host[i])
              for i in (1, 6, 11)]
    pre = router.search(np.stack(q_rows), 5, mode="exact")
    with SearchServer(router, max_batch=len(q_rows), max_delay_s=0.01,
                      topk=5) as srv:
        first = [srv.submit(r) for r in q_rows]
        first = [h.result(timeout=60.0) for h in first]
        gen0 = router.generation
        router.append(extra)
        assert router.generation == gen0 + 1
        second = [srv.submit(r) for r in q_rows]
        second = [h.result(timeout=60.0) for h in second]
    post = router.search(np.stack(q_rows), 5, mode="exact")
    for j, res in enumerate(first):
        assert np.array_equal(res.indices[0], pre.indices[j])
        assert np.array_equal(res.scores[0], pre.scores[j])
    for j, res in enumerate(second):
        assert np.array_equal(res.indices[0], post.indices[j])
        assert np.array_equal(res.scores[0], post.scores[j])


def test_second_router_picks_up_append(growing_router, tmp_path):
    """Two routers over one shard dir model two processes: an append in
    one is visible to the other after refresh(), via the generation."""
    router, extra = growing_router
    other = load_sharded(router.manifest_dir, backend="interpret",
                         corpus_block=64)
    assert other.generation == router.generation
    router.append(extra)
    assert other.n < router.n                    # not yet refreshed
    assert other.refresh() is True
    assert other.n == router.n
    assert other.generation == router.generation
    assert other.refresh() is False              # idempotent
    q = np.ascontiguousarray(
        router.searchers[0].index.words_host[[2, 5]])
    a = router.search(q, 5, mode="exact")
    b = other.search(q, 5, mode="exact")
    assert np.array_equal(a.indices, b.indices)
    assert np.array_equal(a.scores, b.scores)


# ---------------------------------------------------------------------------
# CLI + traffic model
# ---------------------------------------------------------------------------

def test_serve_cli_smoke_flag_both_ways():
    """--smoke defaults on, and --no-smoke can actually turn it off (the
    old action="store_true", default=True made that impossible)."""
    ap = build_parser()
    assert ap.parse_args([]).smoke is True
    assert ap.parse_args(["--smoke"]).smoke is True
    assert ap.parse_args(["--no-smoke"]).smoke is False
    args = ap.parse_args(["--index", "--serve", "--rate", "123",
                          "--max-delay-ms", "2.5"])
    assert args.serve and args.rate == 123.0 and args.max_delay_ms == 2.5
    assert ap.parse_args([]).serve is False
    # multi-worker + admission knobs parse and default sanely
    args = ap.parse_args(["--index", "--serve", "--workers", "4",
                          "--admission", "shed-oldest",
                          "--max-queue", "64",
                          "--deadline-budget-ms", "20"])
    assert args.workers == 4 and args.admission == "shed-oldest"
    assert args.max_queue == 64 and args.deadline_budget_ms == 20.0
    defaults = ap.parse_args([])
    assert defaults.workers is None and defaults.admission == "none"
    with pytest.raises(SystemExit):
        ap.parse_args(["--admission", "drop-everything"])


def test_roofline_search_model():
    """The serving benchmark's analytic roofline terms: corpus-stream
    dominance, linear scaling, and the gap/bandwidth arithmetic."""
    from repro.roofline.search import exact_scan_cost, roofline_gap
    c1 = exact_scan_cost(10_000, 32, 8, topk=10)
    c2 = exact_scan_cost(20_000, 32, 8, topk=10)
    assert c2["corpus_bytes"] == 2 * c1["corpus_bytes"]
    assert c1["corpus_bytes"] == 10_000 * 32 * 4
    assert c2["bytes"] > c1["bytes"] and c2["flops"] == 2 * c1["flops"]
    # batching amortizes the corpus stream: bytes/query shrinks with q
    c_batched = exact_scan_cost(10_000, 32, 64, topk=10)
    assert c_batched["bytes_per_query"] < c1["bytes_per_query"]
    g = roofline_gap(819e9, 2.0, bw=819e9)     # 1s of traffic in 2s
    assert g["gap"] == pytest.approx(2.0)
    assert g["predicted_s"] == pytest.approx(1.0)
    assert g["achieved_gbps"] == pytest.approx(819e9 / 2.0 / 1e9)
    with pytest.raises(ValueError):
        exact_scan_cost(0, 32, 8)
    with pytest.raises(ValueError):
        roofline_gap(0.0, 1.0)


def test_zipfian_traffic_deterministic_and_skewed():
    a = ZipfianTraffic(500, alpha=1.2, seed=7)
    b = ZipfianTraffic(500, alpha=1.2, seed=7)
    ids_a, ids_b = a.ids(400), b.ids(400)
    np.testing.assert_array_equal(ids_a, ids_b)
    assert ids_a.min() >= 0 and ids_a.max() < 500
    # Zipf skew: the most popular id dwarfs the uniform expectation
    top = np.bincount(ids_a).max()
    assert top > 3 * (400 / 500)
    arr = a.arrival_offsets(100, rate_qps=1000.0)
    assert arr.shape == (100,) and np.all(np.diff(arr) > 0)
    assert 0.02 < arr[-1] < 1.0                  # ~100/1000 s, loose bounds
    with pytest.raises(ValueError):
        a.arrival_offsets(5, rate_qps=0.0)
    with pytest.raises(ValueError):
        ZipfianTraffic(0)
