"""Continuous-batching SearchServer + lock-file coordination + live
appends under readers: the PR-6 serving-path promises.

  * micro-batched server results bit-identical to direct ``search()``
    (and the batch triggers: full, aged, deadline, drain),
  * ``FileLock`` mutual exclusion, reentrancy, timeout, stale break,
  * flush racing ``ShardedIndex.append``: every result consistent with
    the pre- OR post-append corpus, never a torn mix; a second router
    picks the append up via the manifest generation,
  * ``--smoke``/``--no-smoke`` actually both parse (the old store_true
    default=True could never be disabled),
  * ``ZipfianTraffic`` determinism and shape.
"""

import glob
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.oph import OPH
from repro.data.lockfile import FileLock, LockTimeout
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import (IndexSearcher, build_index, build_sharded,
                         choose_band_config, load_index, load_sharded)
from repro.launch.serve import build_parser
from repro.launch.server import SearchServer, ServerStats, ZipfianTraffic

K, S, B = 128, 16, 8


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """Synthetic corpus as .sig shards + one single-index searcher."""
    tmp = str(tmp_path_factory.mktemp("server_corpus"))
    spec = DatasetSpec("servertest", n=260, D=1 << S, avg_nnz=48,
                       n_prototypes=6, overlap=0.8, seed=4)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=4)
    fam = OPH.create(jax.random.PRNGKey(1), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    assert len(sig_paths) >= 4
    cfg = choose_band_config(K, B, threshold=0.5)
    idx_path = os.path.join(tmp, "single.idx")
    build_index(sig_paths, idx_path, cfg)
    return tmp, sig_paths, cfg, idx_path


@pytest.fixture(scope="module")
def searcher(corpus):
    _, _, _, idx_path = corpus
    return IndexSearcher(load_index(idx_path), backend="interpret",
                         corpus_block=128)


# ---------------------------------------------------------------------------
# FileLock
# ---------------------------------------------------------------------------

def test_filelock_mutual_exclusion_and_timeout(tmp_path):
    path = str(tmp_path / "x.lock")
    a = FileLock(path)
    b = FileLock(path, timeout_s=0.05, poll_s=0.005)
    with a:
        assert a.held and os.path.exists(path)
        with pytest.raises(LockTimeout):
            b.acquire()
    assert not os.path.exists(path)              # released -> removed
    with b:                                      # free again
        assert b.held


def test_filelock_reentrant(tmp_path):
    lock = FileLock(str(tmp_path / "r.lock"))
    with lock:
        with lock:                               # same instance re-enters
            assert lock.held
        assert lock.held                         # inner exit keeps it
    assert not lock.held


def test_filelock_breaks_stale(tmp_path):
    path = str(tmp_path / "dead.lock")
    with open(path, "w") as f:
        f.write("999999 0")                      # a crashed holder
    old = time.time() - 3600
    os.utime(path, (old, old))
    lock = FileLock(path, timeout_s=1.0, poll_s=0.01, stale_s=60.0)
    with lock:                                   # broke the stale file
        assert lock.held
    # without stale breaking the same file times out
    with open(path, "w") as f:
        f.write("999999 0")
    os.utime(path, (old, old))
    with pytest.raises(LockTimeout):
        FileLock(path, timeout_s=0.05, poll_s=0.005).acquire()


def test_filelock_released_on_generator_abandon(tmp_path, corpus):
    """Abandoning a SignatureCache populate pass mid-epoch must release
    the cache dir's lock (generator close runs the with-block exit)."""
    from repro.data.pipeline import SignatureStream
    from repro.train.online import SignatureCache, make_family
    fam = make_family(jax.random.PRNGKey(0), "oph", K, S)
    raw = sorted(glob.glob(os.path.join(corpus[0], "raw", "*")))
    cache_dir = str(tmp_path / "shared")
    cache = SignatureCache(SignatureStream(raw, fam, b=B, chunk_size=64),
                           cache_dir=cache_dir)
    it = iter(cache)
    next(it)                                     # lock held mid-pass
    assert os.path.exists(os.path.join(cache_dir, ".lock"))
    it.close()
    assert not os.path.exists(os.path.join(cache_dir, ".lock"))
    # a second trainer sharing the dir can now populate immediately
    other = SignatureCache(SignatureStream(raw, fam, b=B, chunk_size=64),
                           cache_dir=cache_dir, lock_timeout_s=1.0)
    assert len(list(other)) > 0 and other.populated


# ---------------------------------------------------------------------------
# SearchServer
# ---------------------------------------------------------------------------

def test_server_bit_identical_to_direct_search(searcher):
    """Micro-batched results == direct search(), row for row."""
    n = searcher.index.n
    picks = [0, 3, n // 2, n - 1, 7, n // 3]
    rows = [np.asarray(searcher.index.words_host[i]) for i in picks]
    direct = searcher.search(np.stack(rows), 5, mode="exact")
    with SearchServer(searcher, max_batch=4, max_delay_s=0.01,
                      topk=5) as srv:
        handles = [srv.submit(r) for r in rows]
        results = [h.result(timeout=60.0) for h in handles]
    for j, res in enumerate(results):
        assert np.array_equal(res.indices[0], direct.indices[j])
        assert np.array_equal(res.scores[0], direct.scores[j])
    assert srv.stats.requests == len(picks)
    assert srv.stats.batches >= 2                # max_batch=4 over 6 reqs


def test_server_full_batch_trigger(searcher):
    """With a huge delay window, only a full queue can flush."""
    rows = [np.asarray(searcher.index.words_host[i]) for i in range(4)]
    with SearchServer(searcher, max_batch=2, max_delay_s=30.0,
                      topk=3) as srv:
        handles = [srv.submit(r) for r in rows]
        t0 = time.monotonic()
        for h in handles:
            h.result(timeout=60.0)
        assert time.monotonic() - t0 < 25.0      # did not wait out the delay
    assert srv.stats.flush_full >= 1
    assert srv.stats.flush_aged == 0


def test_server_aged_trigger_flushes_partial_batch(searcher):
    """A lone request flushes after max_delay_s, not never."""
    row = np.asarray(searcher.index.words_host[1])
    with SearchServer(searcher, max_batch=64, max_delay_s=0.05,
                      topk=3) as srv:
        h = srv.submit(row)
        h.result(timeout=60.0)
    assert srv.stats.flush_aged == 1
    assert srv.stats.flush_full == 0
    assert h.queue_wait_s >= 0.04                # sat out the delay window


def test_server_deadline_trigger(searcher):
    """An explicit deadline flushes before the aging window would."""
    row = np.asarray(searcher.index.words_host[2])
    with SearchServer(searcher, max_batch=64, max_delay_s=30.0,
                      topk=3) as srv:
        t0 = time.monotonic()
        h = srv.submit(row, deadline_s=0.25)
        h.result(timeout=60.0)
        assert time.monotonic() - t0 < 25.0
    assert srv.stats.flush_deadline == 1


def test_server_drains_on_stop(searcher):
    """stop() flushes whatever is queued instead of dropping it."""
    rows = [np.asarray(searcher.index.words_host[i]) for i in (1, 2, 3)]
    srv = SearchServer(searcher, max_batch=64, max_delay_s=30.0,
                       topk=3).start()
    handles = [srv.submit(r) for r in rows]
    srv.stop()
    for h in handles:
        assert h.done()
        assert h.result(timeout=0).indices.shape == (1, 3)
    assert srv.stats.flush_drain >= 1
    with pytest.raises(RuntimeError):
        srv.submit(rows[0])                      # stopped server rejects


def test_server_bad_query_fails_only_itself(searcher):
    """A malformed row errors its own handle; co-batched queries still
    get bit-identical results."""
    good = np.asarray(searcher.index.words_host[5])
    direct = searcher.search(good[None, :], 3, mode="exact")
    with SearchServer(searcher, max_batch=2, max_delay_s=30.0,
                      topk=3) as srv:
        h_bad = srv.submit(np.zeros(3, np.uint32))   # wrong word count
        h_good = srv.submit(good)
        res = h_good.result(timeout=60.0)
        with pytest.raises(ValueError):
            h_bad.result(timeout=60.0)
    assert np.array_equal(res.indices, direct.indices)
    assert np.array_equal(res.scores, direct.scores)
    assert srv.stats.errors == 1


def test_server_requires_start():
    with pytest.raises(RuntimeError, match="not started"):
        SearchServer(object()).submit(np.zeros(1))


def test_server_stats_snapshot(searcher):
    rows = [np.asarray(searcher.index.words_host[i]) for i in range(3)]
    with SearchServer(searcher, max_batch=3, max_delay_s=0.01,
                      topk=3) as srv:
        for h in [srv.submit(r) for r in rows]:
            h.result(timeout=60.0)
    snap = srv.stats.snapshot()
    assert snap["requests"] == 3 and snap["errors"] == 0
    for key in ("latency_p50_ms", "latency_p99_ms", "queue_wait_p50_ms",
                "flush_p50_ms", "mean_batch"):
        assert np.isfinite(snap[key]), key
    assert snap["latency_p99_ms"] >= snap["latency_p50_ms"]
    assert len(srv.stats.queue_wait_s) == 3      # one sample per request


def test_server_stats_reservoir_bounded():
    stats = ServerStats(window=4)
    for i in range(10):
        stats.latency_s.append(float(i))
    assert list(stats.latency_s) == [6.0, 7.0, 8.0, 9.0]


# ---------------------------------------------------------------------------
# Live appends under readers
# ---------------------------------------------------------------------------

@pytest.fixture()
def growing_router(corpus, tmp_path):
    tmp, sig_paths, cfg, _ = corpus
    shard_dir = str(tmp_path / "growing")
    build_sharded(sig_paths[:3], shard_dir, cfg, n_shards=2)
    router = load_sharded(shard_dir, backend="interpret", corpus_block=64)
    return router, sig_paths[3:]


def test_search_racing_append_never_torn(growing_router):
    """Concurrent search() calls during append() return results equal to
    the pre-append OR the post-append corpus -- never a torn mix."""
    router, extra = growing_router
    n0 = router.n
    q = np.ascontiguousarray(
        router.searchers[0].index.words_host[[0, 3, 9, 17]])
    pre = router.search(q, 5, mode="exact")
    results, errors = [], []

    def reader():
        try:
            for _ in range(10):
                results.append(router.search(q, 5, mode="exact"))
        except Exception as e:               # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.02)
    router.append(extra)
    t.join()
    assert not errors
    assert router.n > n0
    post = router.search(q, 5, mode="exact")
    assert not (np.array_equal(pre.indices, post.indices)
                and np.array_equal(pre.scores, post.scores))
    for res in results:
        matches_pre = (np.array_equal(res.indices, pre.indices)
                       and np.array_equal(res.scores, pre.scores))
        matches_post = (np.array_equal(res.indices, post.indices)
                        and np.array_equal(res.scores, post.scores))
        assert matches_pre or matches_post


def test_server_flush_picks_up_append_via_refresh(growing_router):
    """Flushes before the append serve the old corpus, flushes after it
    serve the grown corpus -- the server's per-flush refresh() is the
    reader side of the generation-versioned manifest."""
    router, extra = growing_router
    q_rows = [np.asarray(router.searchers[0].index.words_host[i])
              for i in (1, 6, 11)]
    pre = router.search(np.stack(q_rows), 5, mode="exact")
    with SearchServer(router, max_batch=len(q_rows), max_delay_s=0.01,
                      topk=5) as srv:
        first = [srv.submit(r) for r in q_rows]
        first = [h.result(timeout=60.0) for h in first]
        gen0 = router.generation
        router.append(extra)
        assert router.generation == gen0 + 1
        second = [srv.submit(r) for r in q_rows]
        second = [h.result(timeout=60.0) for h in second]
    post = router.search(np.stack(q_rows), 5, mode="exact")
    for j, res in enumerate(first):
        assert np.array_equal(res.indices[0], pre.indices[j])
        assert np.array_equal(res.scores[0], pre.scores[j])
    for j, res in enumerate(second):
        assert np.array_equal(res.indices[0], post.indices[j])
        assert np.array_equal(res.scores[0], post.scores[j])


def test_second_router_picks_up_append(growing_router, tmp_path):
    """Two routers over one shard dir model two processes: an append in
    one is visible to the other after refresh(), via the generation."""
    router, extra = growing_router
    other = load_sharded(router.manifest_dir, backend="interpret",
                         corpus_block=64)
    assert other.generation == router.generation
    router.append(extra)
    assert other.n < router.n                    # not yet refreshed
    assert other.refresh() is True
    assert other.n == router.n
    assert other.generation == router.generation
    assert other.refresh() is False              # idempotent
    q = np.ascontiguousarray(
        router.searchers[0].index.words_host[[2, 5]])
    a = router.search(q, 5, mode="exact")
    b = other.search(q, 5, mode="exact")
    assert np.array_equal(a.indices, b.indices)
    assert np.array_equal(a.scores, b.scores)


# ---------------------------------------------------------------------------
# CLI + traffic model
# ---------------------------------------------------------------------------

def test_serve_cli_smoke_flag_both_ways():
    """--smoke defaults on, and --no-smoke can actually turn it off (the
    old action="store_true", default=True made that impossible)."""
    ap = build_parser()
    assert ap.parse_args([]).smoke is True
    assert ap.parse_args(["--smoke"]).smoke is True
    assert ap.parse_args(["--no-smoke"]).smoke is False
    args = ap.parse_args(["--index", "--serve", "--rate", "123",
                          "--max-delay-ms", "2.5"])
    assert args.serve and args.rate == 123.0 and args.max_delay_ms == 2.5
    assert ap.parse_args([]).serve is False


def test_zipfian_traffic_deterministic_and_skewed():
    a = ZipfianTraffic(500, alpha=1.2, seed=7)
    b = ZipfianTraffic(500, alpha=1.2, seed=7)
    ids_a, ids_b = a.ids(400), b.ids(400)
    np.testing.assert_array_equal(ids_a, ids_b)
    assert ids_a.min() >= 0 and ids_a.max() < 500
    # Zipf skew: the most popular id dwarfs the uniform expectation
    top = np.bincount(ids_a).max()
    assert top > 3 * (400 / 500)
    arr = a.arrival_offsets(100, rate_qps=1000.0)
    assert arr.shape == (100,) and np.all(np.diff(arr) > 0)
    assert 0.02 < arr[-1] < 1.0                  # ~100/1000 s, loose bounds
    with pytest.raises(ValueError):
        a.arrival_offsets(5, rate_qps=0.0)
    with pytest.raises(ValueError):
        ZipfianTraffic(0)
