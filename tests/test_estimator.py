"""Theorem-1 estimator: constants, roundtrip, variance (Appendix A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Hash2U, bbit_constants, collision_prob,
                        empirical_p_hat, estimate_resemblance, lowest_bits,
                        minhash_signatures, theoretical_variance,
                        theoretical_variance_minwise)
from repro.data import word_pair_sets
from repro.data.sparse import from_lists


def test_sparse_limit_constants():
    """r -> 0  =>  C1 = C2 = 2^-b  (Theorem 1 sparse limit)."""
    for b in (1, 2, 4, 8):
        c = bbit_constants(10, 12, 10**9, b)
        np.testing.assert_allclose(float(c.C1), 2.0 ** -b, rtol=1e-3)
        np.testing.assert_allclose(float(c.C2), 2.0 ** -b, rtol=1e-3)


def test_forward_inverse_roundtrip():
    for R in (0.1, 0.5, 0.9):
        for b in (1, 2, 8):
            pb = collision_prob(R, 5000, 6000, 2**20, b)
            r = estimate_resemblance(pb, 5000, 6000, 2**20, b)
            np.testing.assert_allclose(float(r), R, rtol=1e-5)


@pytest.mark.parametrize(
    "b", [pytest.param(1, marks=pytest.mark.slow),
          pytest.param(2, marks=pytest.mark.slow), 4])
def test_estimator_unbiased_and_variance_matches(b):
    """Empirical MSE over repetitions ~ theoretical variance (App. A).

    The fast tier keeps b=4; b=1,2 add only statistical replication and
    run under -m slow.  The per-repetition pipeline (fresh family ->
    signatures -> p_hat) is jitted once so replication is cheap.
    """
    D, k, n_rep = 2**18, 128, 60
    f1, f2, R = 900, 850, 0.7
    s1, s2 = word_pair_sets(D, f1, f2, R, seed=9)
    true_r = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
    batch = from_lists([s1, s2])

    @jax.jit
    def one_rep(key):
        fam = Hash2U.create(key, k, 18)
        sig = minhash_signatures(batch.indices, batch.mask, fam)
        sb = lowest_bits(sig, b)
        return empirical_p_hat(sb[0], sb[1])

    errs = []
    for rep in range(n_rep):
        p_hat = float(one_rep(jax.random.PRNGKey(1000 + rep)))
        errs.append(float(estimate_resemblance(p_hat, len(s1), len(s2), D, b))
                    - true_r)
    errs = np.asarray(errs)
    mse = np.mean(errs**2)
    var_th = float(theoretical_variance(true_r, len(s1), len(s2), D, b, k))
    # bias should be small and MSE within ~3x of theory (finite reps)
    assert abs(np.mean(errs)) < 3 * np.sqrt(var_th / n_rep) + 0.01
    assert var_th / 3 < mse < var_th * 3, (mse, var_th)


def test_bbit_variance_larger_than_minwise():
    """b-bit estimator has higher variance per hash (the b vs k tradeoff)."""
    R, k = 0.5, 100
    v1 = float(theoretical_variance(R, 100, 100, 2**30, 1, k))
    vm = float(theoretical_variance_minwise(R, k))
    assert v1 > vm
    # storage-normalized: 1-bit at 64x the hashes beats 64-bit minwise
    assert float(theoretical_variance(R, 100, 100, 2**30, 1, 64 * k)) < vm
