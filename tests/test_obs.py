"""The observability layer: registry semantics, tracer span trees under
concurrent dispatch workers, and the HTTP exporter.

The serving-path integration matters most here: ISSUE 9's acceptance is
that a traced multi-worker run produces (a) per-request span trees whose
direct children partition the recorded end-to-end latency (±5%), (b)
spans that never tear across workers (ids consistent, clocks monotonic),
(c) counter totals identical across ``num_workers`` ∈ {1, 4} for the
same seeded traffic, and (d) results bit-identical to direct
``search()``.
"""

import collections
import glob
import json
import os
import threading
import urllib.request

import numpy as np
import pytest

import jax

from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import (IndexSearcher, build_index, build_sharded,
                         choose_band_config, load_index, load_sharded)
from repro.launch.server import SearchServer, ZipfianTraffic
from repro.obs.export import start_http_exporter
from repro.obs.metrics import MetricsRegistry, Sample, get_registry
from repro.obs.trace import Tracer, get_tracer, request_tree
from repro.train.online import make_family


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("obs_test_total", "a counter")
    c.inc()
    c.inc(2.5)
    g = reg.gauge("obs_depth", "a gauge")
    g.set(7)
    g.dec(2)
    h = reg.histogram("obs_lat_seconds", "a histogram")
    for v in range(100):
        h.observe(v / 100)
    vals = reg.values()
    assert vals["obs_test_total"] == 3.5
    assert vals["obs_depth"] == 5.0
    assert vals["obs_lat_seconds_count"] == 100
    assert vals["obs_lat_seconds_sum"] == pytest.approx(49.5)
    assert vals['obs_lat_seconds{quantile="0.5"}'] == pytest.approx(0.5, abs=0.05)


def test_counter_rejects_negative_and_type_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("obs_mono_total", "monotone")
    with pytest.raises(ValueError):
        c.inc(-1)
    with pytest.raises(ValueError):
        reg.gauge("obs_mono_total", "same name, different type")


def test_labeled_children_and_prometheus_text():
    reg = MetricsRegistry()
    fam = reg.counter("obs_flushes_total", "flushes", labels=("trigger",))
    fam.labels(trigger="full").inc(3)
    fam.labels(trigger="aged").inc()
    text = reg.prometheus_text()
    assert "# TYPE obs_flushes_total counter" in text
    assert 'obs_flushes_total{trigger="full"} 3' in text
    assert 'obs_flushes_total{trigger="aged"} 1' in text


def test_weakref_collector_lives_and_dies_with_holder():
    reg = MetricsRegistry()

    class Holder:
        n = 5

    def collect(h):
        yield Sample("obs_holder_n", "gauge", "held value", (), float(h.n))

    h = Holder()
    reg.register_object(h, collect)
    assert reg.values()["obs_holder_n"] == 5.0
    del h
    assert "obs_holder_n" not in reg.values()


def test_snapshot_sums_identical_series_across_holders():
    reg = MetricsRegistry()

    def collect(h):
        yield Sample("obs_shared_total", "counter", "shared", (), 2.0)

    class Holder:
        pass

    a, b = Holder(), Holder()
    reg.register_object(a, collect)
    reg.register_object(b, collect)
    assert reg.values()["obs_shared_total"] == 4.0
    del a, b  # keep referenced until here


def test_reset_clears_values_but_keeps_live_collectors():
    reg = MetricsRegistry()
    reg.counter("obs_gone_total", "cleared by reset").inc(9)

    class Holder:
        pass

    def collect(h):
        yield Sample("obs_kept", "gauge", "survives reset", (), 1.0)

    h = Holder()
    reg.register_object(h, collect)
    reg.reset()
    vals = reg.values()
    assert "obs_gone_total" not in vals
    assert vals["obs_kept"] == 1.0
    del h


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_disabled_tracer_emits_nothing():
    tr = Tracer(enabled=False)
    with tr.span("outer"):
        sp = tr.start_span("inner")
        tr.end_span(sp)
    tr.add_span("retro", 0.0, 1.0)
    assert tr.events() == []


def test_span_kinds_and_nesting():
    tr = Tracer(enabled=True)
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            assert inner.parent_id == outer.span_id
    root = tr.start_span("request", kind="async")
    root.trace_id = root.span_id
    child = tr.start_span("flush", parent=root, kind="async")
    assert child.trace_id == root.trace_id
    tr.end_span(child)
    tr.end_span(root)
    phs = collections.Counter(e["ph"] for e in tr.events())
    assert phs["X"] == 2                       # outer + inner
    assert phs["b"] == 2 and phs["e"] == 2     # request + flush


def test_phase_channel_drains_per_thread():
    tr = Tracer(enabled=True)
    with tr.phase("mesh_dispatch"):
        pass
    with tr.phase("merge"):
        pass
    phases = tr.take_phases()
    assert [p[0] for p in phases] == ["mesh_dispatch", "merge"]
    assert all(t1 >= t0 for _, t0, t1 in phases)
    assert tr.take_phases() == []              # drained

    got = {}

    def other():
        got["phases"] = tr.take_phases()

    t = threading.Thread(target=other)
    with tr.phase("mine"):
        pass
    t.start()
    t.join()
    assert got["phases"] == []                 # phase notes are per-thread
    assert [p[0] for p in tr.take_phases()] == ["mine"]


def test_bounded_buffer_counts_drops():
    tr = Tracer(enabled=True, max_events=4)
    for i in range(10):
        tr.add_span(f"s{i}", 0.0, 1.0)
    assert len(tr.events()) <= 4
    assert tr.dropped > 0


# ---------------------------------------------------------------------------
# HTTP exporter
# ---------------------------------------------------------------------------

def _get(url: str) -> bytes:
    with urllib.request.urlopen(url, timeout=10.0) as r:
        return r.read()


def test_exporter_serves_metrics_json_trace_and_health():
    reg = MetricsRegistry()
    reg.counter("obs_http_total", "served").inc(2)
    tr = Tracer(enabled=True)
    tr.add_span("hello", 0.0, 0.001)
    with start_http_exporter(port=0, registry=reg, tracer=tr) as exp:
        assert _get(exp.url + "/healthz") == b"ok"
        text = _get(exp.url + "/metrics").decode()
        assert "obs_http_total 2" in text
        snap = json.loads(_get(exp.url + "/metrics.json"))
        assert snap["obs_http_total"]["samples"][0]["value"] == 2.0
        doc = json.loads(_get(exp.url + "/trace"))
        assert doc["traceEvents"][0]["name"] == "hello"
        with pytest.raises(urllib.error.HTTPError):
            _get(exp.url + "/nope")


# ---------------------------------------------------------------------------
# Serving integration: traced SearchServer over a real index
# ---------------------------------------------------------------------------

K, B, S = 64, 8, 16
N_DOCS = 512
TOPK = 5


@pytest.fixture(scope="module")
def small_index(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("obs_idx")
    spec = DatasetSpec("obs_serving", n=N_DOCS, D=1 << S, avg_nnz=32,
                       n_prototypes=4, overlap=0.8, seed=0)
    raw = make_sharded_dataset(spec, str(tmp / "raw"), n_shards=2)
    fam = make_family(jax.random.PRNGKey(0), "oph", K, S,
                      densify="rotation")
    preprocess_shards(raw, str(tmp / "sig"), fam, b=B, chunk_size=256)
    sig = sorted(glob.glob(str(tmp / "sig" / "*.sig")))
    cfg = choose_band_config(K, B, code_bits=B, threshold=0.5)
    build_index(sig, str(tmp / "c.idx"), cfg)
    index = load_index(str(tmp / "c.idx"))
    return index, IndexSearcher(index)


def _drive_traced(searcher, index, *, workers: int, n: int = 48):
    reg = MetricsRegistry()
    tr = Tracer(enabled=True)
    traffic = ZipfianTraffic(int(index.words_host.shape[0]),
                             alpha=1.1, seed=7)
    ids = traffic.ids(n)
    server = SearchServer(searcher, max_batch=8, max_delay_s=0.002,
                          topk=TOPK, mode="exact", num_workers=workers,
                          registry=reg, tracer=tr)
    with server:
        handles = [server.submit(np.asarray(index.words_host[int(i)]))
                   for i in ids]
        results = [h.result(timeout=60.0) for h in handles]
    # the registry holds only a weakref to the server; hand the server
    # back so callers can still collect its samples
    return reg, tr, ids, results, server


def test_multiworker_spans_never_tear(small_index):
    """Concurrent workers: every request tree has exactly one root, all
    parent ids resolve inside the same trace, clocks are monotonic per
    span, and the direct children partition the root (±5%)."""
    index, searcher = small_index
    reg, tr, ids, _, _srv = _drive_traced(searcher, index, workers=4)

    events = tr.events()
    assert tr.dropped == 0
    by_id = {}
    for ev in events:
        args = ev["args"]
        by_id.setdefault(args["span_id"], []).append(ev)
    # every span's begin/end carry the same identity, and t1 >= t0
    for span_id, evs in by_id.items():
        ts = sorted(e["ts"] for e in evs)
        assert ts[-1] >= ts[0]
        assert len({(e["args"]["parent_id"], e["args"]["trace_id"])
                    for e in evs}) == 1

    trees = request_tree(events)
    trees.pop(0, None)                       # batch-level (X) spans
    assert len(trees) == len(ids)
    for tid, evs in trees.items():
        begins = [e for e in evs if e["ph"] == "b"]
        ends = {e["args"]["span_id"]: e for e in evs if e["ph"] == "e"}
        roots = [e for e in begins if e["name"] == "request"]
        assert len(roots) == 1               # exactly one root per request
        root = roots[0]
        span_ids = {e["args"]["span_id"] for e in begins}
        for e in begins:                     # parents resolve in-tree
            if e is not root:
                assert e["args"]["parent_id"] in span_ids
        kids = [e for e in begins
                if e["args"]["parent_id"] == root["args"]["span_id"]]
        assert sorted(e["name"] for e in kids) == ["admission", "flush",
                                                   "queue"]
        root_dur = ends[root["args"]["span_id"]]["ts"] - root["ts"]
        ksum = sum(ends[e["args"]["span_id"]]["ts"] - e["ts"]
                   for e in kids)
        if root_dur > 0:
            assert abs(ksum - root_dur) <= 0.05 * root_dur


def test_counter_totals_identical_across_worker_counts(small_index):
    """Same seeded traffic through 1 vs 4 workers: identical request /
    shed / degraded / error totals, identical summed batch sizes, and
    bit-identical results."""
    index, searcher = small_index
    totals = {}
    results = {}
    for nw in (1, 4):
        reg, _, ids, res, _srv = _drive_traced(searcher, index, workers=nw)
        vals = reg.values()
        totals[nw] = {k: vals[k] for k in
                      ("serve_requests_total", "serve_shed_total",
                       "serve_degraded_total", "serve_errors_total",
                       "serve_batch_size_sum")}
        results[nw] = res
    assert totals[1] == totals[4]
    assert totals[1]["serve_requests_total"] == 48.0
    for a, b in zip(results[1], results[4]):
        assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
        assert np.array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_server_exports_roofline_and_occupancy(small_index):
    index, searcher = small_index
    reg, _, _, _, _srv = _drive_traced(searcher, index, workers=2)
    vals = reg.values()
    assert vals["serve_roofline_predicted_bytes"] > 0
    assert vals["serve_roofline_gap"] > 0
    assert 'serve_worker_occupancy{worker="0"}' in vals
    assert 'serve_worker_occupancy{worker="1"}' in vals
    assert vals["serve_queue_depth"] == 0.0    # drained at close


def test_trace_counts_alias_still_behaves_like_the_old_dict(small_index):
    """S1 back-compat: ``query.TRACE_COUNTS`` reads/writes route through
    the registry but keep the mapping idiom the old tests rely on."""
    from repro.index import query

    before = query.TRACE_COUNTS["exact_scan"]
    query.TRACE_COUNTS["exact_scan"] += 1
    assert query.TRACE_COUNTS["exact_scan"] == before + 1
    assert "exact_scan" in query.TRACE_COUNTS
    assert set(query.TRACE_COUNTS.keys()) == {"exact_scan"}
    with pytest.raises(ValueError):
        query.TRACE_COUNTS["exact_scan"] = 0   # counters are monotone
    # the same series is visible in the registry snapshot
    vals = get_registry().values()
    assert vals["index_exact_scan_retraces_total"] == before + 1


# ---------------------------------------------------------------------------
# Multidevice acceptance: mesh router + 4 workers, scraped live
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_mesh_serving_scrape_and_trace(host_devices, tmp_path):
    """ISSUE 9 acceptance: a seeded serving run on the device mesh with
    4 workers yields a Prometheus scrape carrying queue-depth,
    shed/degraded, per-worker occupancy, mesh dispatch counters, and
    roofline gauges; a trace whose request trees cover
    admission→flush→dispatch→merge and partition the latency (±5%); and
    bit-identical results vs direct search()."""
    from repro.launch.mesh import make_debug_mesh

    spec = DatasetSpec("obs_mesh", n=N_DOCS, D=1 << S, avg_nnz=32,
                       n_prototypes=4, overlap=0.8, seed=0)
    raw = make_sharded_dataset(spec, str(tmp_path / "raw"), n_shards=2)
    fam = make_family(jax.random.PRNGKey(0), "oph", K, S,
                      densify="rotation")
    preprocess_shards(raw, str(tmp_path / "sig"), fam, b=B, chunk_size=256)
    sig = sorted(glob.glob(str(tmp_path / "sig" / "*.sig")))
    cfg = choose_band_config(K, B, code_bits=B, threshold=0.5)
    build_sharded(sig, str(tmp_path / "shards"), cfg, n_shards=2)
    mesh = make_debug_mesh(2, axes=("data",))
    router = load_sharded(str(tmp_path / "shards"), mesh=mesh)

    def words_of(i):
        offsets = list(router.offsets) + [router.n]
        shard = int(np.searchsorted(offsets, i, side="right")) - 1
        return np.asarray(
            router.searchers[shard].index.words_host[i - offsets[shard]])

    # production wiring: the router registered itself into the DEFAULT
    # registry at construction, so scrape that one (conftest's _reset_obs
    # fixture cleans both singletons up afterwards)
    reg = get_registry()
    tr = get_tracer()
    tr.reset(enabled=True)
    traffic = ZipfianTraffic(router.n, alpha=1.1, seed=11)
    ids = traffic.ids(48)
    server = SearchServer(router, max_batch=8, max_delay_s=0.002,
                          topk=TOPK, mode="exact", num_workers=4)
    with start_http_exporter(port=0, registry=reg, tracer=tr) as exp:
        with server:
            handles = [server.submit(words_of(int(i))) for i in ids]
            results = [h.result(timeout=120.0) for h in handles]
            live = _get(exp.url + "/metrics").decode()   # scrape under load
        final = _get(exp.url + "/metrics").decode()

    for text in (live, final):
        for name in ("serve_queue_depth", "serve_shed_total",
                     "serve_degraded_total", "serve_worker_occupancy",
                     "index_mesh_dispatches_total", "serve_roofline_gap"):
            assert name in text, f"{name} missing from scrape"
    assert 'index_mesh_dispatches_total{mode="exact"}' in final
    assert reg.values()["index_mesh_dispatches_total{mode=\"exact\"}"] > 0

    # span trees: children cover dispatch+merge and partition latency
    trees = request_tree(tr.events())
    trees.pop(0, None)
    assert len(trees) == len(ids)
    saw_mesh = saw_merge = False
    for tid, evs in trees.items():
        begins = [e for e in evs if e["ph"] == "b"]
        ends = {e["args"]["span_id"]: e for e in evs if e["ph"] == "e"}
        root = next(e for e in begins if e["name"] == "request")
        kids = [e for e in begins
                if e["args"]["parent_id"] == root["args"]["span_id"]]
        assert sorted(e["name"] for e in kids) == ["admission", "flush",
                                                   "queue"]
        flush = next(e for e in kids if e["name"] == "flush")
        under_flush = {e["name"] for e in begins
                       if e["args"]["parent_id"]
                       == flush["args"]["span_id"]}
        saw_mesh |= "mesh_dispatch" in under_flush
        saw_merge |= "merge" in under_flush
        root_dur = ends[root["args"]["span_id"]]["ts"] - root["ts"]
        ksum = sum(ends[e["args"]["span_id"]]["ts"] - e["ts"]
                   for e in kids)
        if root_dur > 0:
            assert abs(ksum - root_dur) <= 0.05 * root_dur
    assert saw_mesh and saw_merge

    # trace JSON is valid trace-event format
    out = tmp_path / "trace.json"
    tr.export(str(out))
    doc = json.loads(out.read_text())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    assert all({"name", "ph", "ts", "pid", "tid"} <= set(e)
               for e in doc["traceEvents"])

    # still bit-identical to direct search
    direct = router.search(np.stack([words_of(int(i)) for i in ids]),
                           TOPK, mode="exact")
    for j, res in enumerate(results):
        assert np.array_equal(np.asarray(res.indices[0]),
                              np.asarray(direct.indices[j]))
        assert np.array_equal(np.asarray(res.scores[0]),
                              np.asarray(direct.scores[j]))
