"""Data pipeline: shard formats, chunked iteration, prefetch, stragglers."""

import numpy as np
import pytest

import random
import time

from repro.data import TINY, generate
from repro.data.pipeline import (ChunkedLoader, LoaderStats,
                                 make_sharded_dataset, read_shard_binary,
                                 read_shard_libsvm, read_with_retries,
                                 write_shard_binary, write_shard_libsvm,
                                 write_shards)


def _toy_sets(n=50, seed=0):
    rng = np.random.default_rng(seed)
    sets = [np.sort(rng.choice(1000, size=rng.integers(3, 30), replace=False))
            for _ in range(n)]
    labels = rng.choice([-1.0, 1.0], n).astype(np.float32)
    return sets, labels


@pytest.mark.parametrize("fmt", ["binary", "libsvm"])
def test_shard_roundtrip(tmp_path, fmt):
    sets, labels = _toy_sets()
    path = str(tmp_path / ("s.npz" if fmt == "binary" else "s.txt"))
    writer = write_shard_binary if fmt == "binary" else write_shard_libsvm
    reader = read_shard_binary if fmt == "binary" else read_shard_libsvm
    writer(path, sets, labels)
    got_sets, got_labels = reader(path)
    np.testing.assert_array_equal(got_labels, labels)
    for a, b in zip(got_sets, sets):
        np.testing.assert_array_equal(np.asarray(a, np.int64), b)


@pytest.mark.parametrize("prefetch", [0, 2])
def test_chunked_iteration(tmp_path, prefetch):
    sets, labels = _toy_sets(101)
    paths = write_shards(sets, labels, str(tmp_path), n_shards=4)
    loader = ChunkedLoader(paths, chunk_size=25, prefetch=prefetch,
                           lane_multiple=8)
    chunks = list(loader)
    assert sum(c.n for c in chunks) == 101
    assert chunks[0].n == 25
    # labels preserved in order
    all_labels = np.concatenate([np.asarray(c.labels) for c in chunks])
    np.testing.assert_array_equal(all_labels, labels)
    assert loader.stats.chunks == len(chunks)
    assert loader.stats.load_seconds > 0


def test_straggler_detection_counters(tmp_path):
    sets, labels = _toy_sets(40)
    paths = write_shards(sets, labels, str(tmp_path), n_shards=2)
    # absurd deadline of 0 -> every read is a straggler, then reassigned
    loader = ChunkedLoader(paths, chunk_size=40, prefetch=0,
                           straggler_deadline_s=0.0, max_retries=1,
                           lane_multiple=8)
    chunks = list(loader)
    assert sum(c.n for c in chunks) == 40
    assert loader.stats.straggler_retries >= 2
    assert loader.stats.shard_reassignments == 2


def test_read_shard_oserror_accounted(tmp_path):
    """Flaky reads retry with accounting; exhausted retries raise."""
    sets, labels = _toy_sets(20)
    paths = write_shards(sets, labels, str(tmp_path), n_shards=1)
    loader = ChunkedLoader(paths, chunk_size=20, prefetch=0, max_retries=2,
                           lane_multiple=8)
    real_reader = loader._reader
    fails = {"n": 2}

    def flaky(path):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient read failure")
        return real_reader(path)

    loader._reader = flaky
    chunks = list(loader)
    assert sum(c.n for c in chunks) == 20
    assert loader.stats.io_errors == 2
    # the successful attempt is fully accounted (no silent re-read)
    assert loader.stats.load_seconds > 0 and loader.stats.bytes_read > 0

    # every attempt failing must surface the OSError, all attempts counted
    dead = ChunkedLoader(paths, chunk_size=20, prefetch=0, max_retries=1,
                         lane_multiple=8)

    def always_fails(path):
        raise OSError("gone")

    dead._reader = always_fails
    with pytest.raises(OSError):
        list(dead)
    assert dead.stats.io_errors == 2  # max_retries + 1 attempts
    assert dead.stats.bytes_read == 0


def test_io_backoff_schedule_pinned(tmp_path):
    """Fake-clock regression of the retry backoff: attempt ``i`` sleeps
    ``min(cap, base * 2**i)`` scaled by the rng's uniform [0.5, 1.0)
    jitter -- pinned against a replay of the same seeded rng.  No sleep
    after the final failed attempt, and none on the straggler path."""
    calls = {"n": 0}

    def flaky(path):
        calls["n"] += 1
        raise OSError("down")

    sleeps = []
    stats = LoaderStats()
    with pytest.raises(OSError):
        read_with_retries(flaky, "p", stats, deadline=30.0, max_retries=3,
                          backoff_base_s=0.05, backoff_cap_s=0.12,
                          rng=random.Random(7), sleep=sleeps.append)
    assert calls["n"] == 4 and stats.io_errors == 4
    replay = random.Random(7)
    want = [min(0.12, 0.05 * 2.0 ** i) * (0.5 + 0.5 * replay.random())
            for i in range(3)]             # one sleep per retry, capped,
    assert sleeps == want                  # none after the last failure

    # stragglers retry immediately: a 0-second deadline forces retries
    # on every (successful) read, and the sleep clock must never tick
    sleeps.clear()
    real = tmp_path / "shard"
    real.write_bytes(b"x" * 16)
    out = read_with_retries(lambda p: "ok", str(real), LoaderStats(),
                            deadline=0.0, max_retries=2,
                            backoff_base_s=0.05, backoff_cap_s=0.12,
                            rng=random.Random(7), sleep=sleeps.append)
    assert out == "ok" and sleeps == []


def test_loader_backoff_knobs_reach_reader(tmp_path):
    """ChunkedLoader threads its io_backoff_* knobs into the shared
    retry helper -- the sleeps a flaky shard sees follow the loader's
    configured base/cap, not the defaults."""
    sets, labels = _toy_sets(20)
    paths = write_shards(sets, labels, str(tmp_path), n_shards=1)
    loader = ChunkedLoader(paths, chunk_size=20, prefetch=0, max_retries=2,
                           lane_multiple=8, io_backoff_base_s=1e-4,
                           io_backoff_cap_s=2e-4)
    real_reader = loader._reader
    fails = {"n": 2}

    def flaky(path):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient")
        return real_reader(path)

    loader._reader = flaky
    t0 = time.perf_counter()
    chunks = list(loader)
    dt = time.perf_counter() - t0
    assert sum(c.n for c in chunks) == 20
    assert loader.stats.io_errors == 2
    assert dt < 1.0                      # default base (50ms) not in play


def test_make_sharded_dataset(tmp_path):
    paths = make_sharded_dataset(TINY, str(tmp_path), n_shards=3, n=60)
    assert len(paths) == 3
    loader = ChunkedLoader(paths, chunk_size=16, lane_multiple=8)
    total = sum(c.n for c in loader)
    assert total == 48  # 80% train split of 60


def test_binary_faster_than_text(tmp_path):
    """The paper's observation: binary loading beats LibSVM text."""
    import time
    sets, labels = _toy_sets(2000, seed=3)
    pb = write_shards(sets, labels, str(tmp_path / "b"), 1, fmt="binary")
    pt = write_shards(sets, labels, str(tmp_path / "t"), 1, fmt="libsvm")
    t0 = time.perf_counter(); read_shard_binary(pb[0]); tb = time.perf_counter() - t0
    t0 = time.perf_counter(); read_shard_libsvm(pt[0]); tt = time.perf_counter() - t0
    assert tb < tt  # text parsing is slower


@pytest.mark.parametrize("n,chunk_size", [(101, 25), (96, 16), (30, 64)])
def test_chunk_contents_pinned(tmp_path, n, chunk_size):
    """Chunk boundaries AND per-row set contents must equal slicing the
    concatenated shard stream -- pins that the O(n) moving-cursor chunk
    assembly (no per-chunk list re-copy) changed nothing observable."""
    sets, labels = _toy_sets(n, seed=3)
    paths = write_shards(sets, labels, str(tmp_path), n_shards=4)
    loader = ChunkedLoader(paths, chunk_size=chunk_size, prefetch=0,
                           lane_multiple=8)
    chunks = list(loader)
    sizes = [c.n for c in chunks]
    assert sizes[:-1] == [chunk_size] * (len(chunks) - 1)
    assert sum(sizes) == n
    pos = 0
    for c in chunks:
        idx = np.asarray(c.indices)
        mask = np.asarray(c.mask)
        for row in range(c.n):
            got = np.sort(idx[row][mask[row]])
            np.testing.assert_array_equal(got, np.sort(sets[pos + row]))
        np.testing.assert_array_equal(np.asarray(c.labels),
                                      labels[pos:pos + c.n])
        pos += c.n
