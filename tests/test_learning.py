"""Linear learning on hashed features: parity + accuracy (paper §4-§6)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Hash2U, Hash4U, PermutationFamily, VWHasher,
                        expand_onehot, lowest_bits, minhash_signatures)
from repro.data import TINY, generate
from repro.models.linear import (LinearModel, accuracy, asgd_model,
                                 dense_margin, hashed_margin, make_loss_fn,
                                 sgd_svm_init, sgd_svm_step)
from repro.optim import adamw, constant
from repro.train import TrainState, Trainer, make_train_step


@pytest.fixture(scope="module")
def tiny_data():
    train, test = generate(TINY)
    return train, test


def _signatures(batch, fam, b):
    return lowest_bits(minhash_signatures(batch.indices, batch.mask, fam), b)


def test_hashed_margin_equals_explicit_expansion(tiny_data):
    train, _ = tiny_data
    k, b = 32, 4
    fam = Hash2U.create(jax.random.PRNGKey(0), k, 16)
    sig = _signatures(train, fam, b)
    model = LinearModel(
        w=jax.random.normal(jax.random.PRNGKey(1), (k * 2**b,)),
        bias=jnp.float32(0.3))
    implicit = hashed_margin(model, sig, b)
    explicit = dense_margin(model, expand_onehot(sig, b) / jnp.sqrt(float(k)))
    np.testing.assert_allclose(np.asarray(implicit), np.asarray(explicit),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize(
    "kind", ["svm", pytest.param("logistic", marks=pytest.mark.slow)])
def test_batch_training_reaches_accuracy(tiny_data, kind):
    train, test = tiny_data
    k, b = 128, 8
    fam = Hash2U.create(jax.random.PRNGKey(2), k, 16)
    sig_tr, sig_te = _signatures(train, fam, b), _signatures(test, fam, b)
    loss = make_loss_fn(kind, "hashed", b, C=1.0)
    opt = adamw(constant(0.05))
    state = TrainState.create(LinearModel.create(k * 2**b), opt)
    step = make_train_step(lambda p, batch: loss(p, *batch), opt)
    tr = Trainer(step)
    state = tr.fit(state, lambda: iter([(sig_tr, train.labels)] * 120), 120)
    acc = float(accuracy(state.params, sig_te, test.labels,
                         feature_kind="hashed", b=b))
    assert acc > 0.9, acc


@pytest.mark.slow
def test_hash_families_learning_parity(tiny_data):
    """Paper Fig. 4: perm / 2U / 4U give matching accuracies (k,b large)."""
    train, test = tiny_data
    k, b = 128, 8
    accs = {}
    for name, fam in [
        ("perm", PermutationFamily.create(jax.random.PRNGKey(4), k, 2**16)),
        ("2u", Hash2U.create(jax.random.PRNGKey(5), k, 16)),
        ("4u", Hash4U.create(jax.random.PRNGKey(6), k, 16)),
    ]:
        sig_tr, sig_te = _signatures(train, fam, b), _signatures(test, fam, b)
        loss = make_loss_fn("svm", "hashed", b, C=1.0)
        opt = adamw(constant(0.05))
        state = TrainState.create(LinearModel.create(k * 2**b), opt)
        step = make_train_step(lambda p, batch: loss(p, *batch), opt)
        state = Trainer(step).fit(
            state, lambda: iter([(sig_tr, train.labels)] * 100), 100)
        accs[name] = float(accuracy(state.params, sig_te, test.labels,
                                    feature_kind="hashed", b=b))
    vals = list(accs.values())
    assert max(vals) - min(vals) < 0.08, accs


def test_online_sgd_and_asgd(tiny_data):
    train, test = tiny_data
    k, b = 128, 8
    fam = Hash2U.create(jax.random.PRNGKey(7), k, 16)
    sig_tr, sig_te = _signatures(train, fam, b), _signatures(test, fam, b)
    state = sgd_svm_init(k * 2**b, avg_start=100.0)
    step = jax.jit(functools.partial(sgd_svm_step, lam=1e-4, eta0=0.5, b=b,
                                     average=True))
    for _ in range(20):
        for i in range(0, train.n, 16):
            state = step(state, sig_tr[i:i + 16], train.labels[i:i + 16])
    acc_last = float(accuracy(state.model, sig_te, test.labels,
                              feature_kind="hashed", b=b))
    acc_avg = float(accuracy(asgd_model(state), sig_te, test.labels,
                             feature_kind="hashed", b=b))
    assert acc_last > 0.85 and acc_avg > 0.85


@pytest.mark.slow
def test_vw_learning(tiny_data):
    """VW baseline trains on dense hashed vectors (paper §4.2)."""
    train, test = tiny_data
    vw = VWHasher.create(jax.random.PRNGKey(8), m_bits=10, mode="u2")
    x_tr = vw(train.indices, train.mask)
    x_te = vw(test.indices, test.mask)
    loss = make_loss_fn("logistic", "dense", 0, C=1.0)
    opt = adamw(constant(0.05))
    state = TrainState.create(LinearModel.create(vw.m), opt)
    step = make_train_step(lambda p, batch: loss(p, *batch), opt)
    state = Trainer(step).fit(
        state, lambda: iter([(x_tr, train.labels)] * 100), 100)
    acc = float(accuracy(state.params, x_te, test.labels,
                         feature_kind="dense"))
    assert acc > 0.85, acc
