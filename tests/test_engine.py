"""SignatureEngine: dispatch parity, packed wire format, backends, tuning.

Four layers:

  * pack/unpack round-trip sweeps: b in {1,2,4,8,16} x non-word-aligned k
    x sentinel (b+1)-bit codes, plus the in-kernel fused pack vs the jnp
    bitstream pack,
  * engine-vs-reference bit-exactness across every (scheme, family,
    densify, b) combination (the legacy ``batch_signatures`` contract),
  * backend registry semantics (auto resolution, gpu fallback, ref) and
    TuningTable JSON persistence,
  * the ``.sig`` shard format round-trip (plain + mmap) and the
    layering rule that only ``repro/kernels/`` touches ``*_pallas``.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bbit import pack_codes, packed_words, unpack_codes
from repro.core.hashing import Hash2U, Hash4U
from repro.core.minhash import minhash_signatures
from repro.core.oph import EMPTY, OPH, oph_signatures
from repro.data.sparse import from_lists
from repro.kernels import (BACKENDS, PackSpec, PackedSignatures,
                           SignatureEngine, TuningTable, batch_signatures,
                           resolve_backend)
from repro.kernels.pack import pack_device, unpack_device

RNG = np.random.default_rng(23)


def _batch(n=5, max_set=250, s=16, seed=101, max_nnz=256):
    rng = np.random.default_rng(seed)
    sets = [rng.choice(1 << s, rng.integers(1, max_set + 1), replace=False)
            for _ in range(n)]
    return from_lists(sets, max_nnz=max_nnz)


@pytest.fixture(scope="module")
def batch16():
    return _batch()           # same shape as test_oph's fixture: jit reuse


# ---------------------------------------------------------------------------
# Wire format: bitstream round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("k", [60, 128, 129])       # non-word-aligned + aligned
@pytest.mark.parametrize("sentinel", [False, True])
def test_pack_roundtrip_sweep(b, k, sentinel):
    """(b, k, sentinel) sweep: pack -> unpack is the identity, at exactly
    ceil(k*code_bits/32) words per example."""
    rng = np.random.default_rng(b * 1000 + k)
    sig = rng.integers(0, 1 << b, (7, k)).astype(np.uint32)
    if sentinel:
        sig[rng.random((7, k)) < 0.3] = np.uint32(0xFFFFFFFF)   # EMPTY
    spec = PackSpec(k, b, sentinel)
    assert spec.code_bits == (b + 1 if sentinel else b)
    packed = pack_device(jnp.asarray(sig), spec)
    assert packed.shape == (7, packed_words(k, spec.code_bits))
    assert packed.dtype == jnp.uint32
    out = np.asarray(unpack_device(packed, spec))
    assert np.array_equal(out, sig)


def test_pack_codes_bit_layout():
    """Code j occupies bits [j*cb, (j+1)*cb) -- checked against a python
    big-integer bitstream, including word-straddling 9-bit codes."""
    k, cb = 23, 9
    v = np.arange(k, dtype=np.uint32) * 21 % (1 << cb)
    p = np.asarray(pack_codes(jnp.asarray(v[None, :]), cb))[0]
    stream = 0
    for j in range(k):
        stream |= int(v[j]) << (j * cb)
    for w in range(p.size):
        assert int(p[w]) == (stream >> (32 * w)) & 0xFFFFFFFF
    assert np.array_equal(
        np.asarray(unpack_codes(jnp.asarray(p[None, :]), cb, k))[0], v)


def test_fused_kernel_pack_matches_jnp_pack(batch16):
    """Lane-aligned minhash: the in-kernel final-step pack bit-equals the
    jnp bitstream pack of the unpacked signatures."""
    fam = Hash2U.create(jax.random.PRNGKey(0), 128, 16)
    sig = batch_signatures(batch16, fam, b=8)
    eng = SignatureEngine(fam, b=8, packed=True)
    p = eng.packed_signatures(batch16)
    assert np.array_equal(np.asarray(p.data),
                          np.asarray(pack_codes(sig, 8)))
    assert np.array_equal(np.asarray(p.unpack()), np.asarray(sig))


# ---------------------------------------------------------------------------
# Engine vs reference: every (scheme, family, densify, b)
# ---------------------------------------------------------------------------

_GRID = [("minhash", fam, None, b)
         for fam in ("2u", "4u") for b in (0, 8)] + \
        [("oph", fam, densify, b)
         for fam in ("2u", "4u")
         for densify in ("rotation", "sentinel", "optimal", "fast")
         for b in (0, 8)]
# fast tier: every b=8 row (all schemes/densify modes) + the minhash-2u
# baseline; the full product (b=0 rows, 4u duplicates) runs in the slow tier
_GRID = [pytest.param(*row, marks=[] if (row[3] == 8 or
                                         row[:2] == ("minhash", "2u"))
                      else [pytest.mark.slow])
        for row in _GRID]


def _make_family(scheme, fam, densify, k, s):
    import zlib
    key = jax.random.PRNGKey(
        zlib.crc32(repr((scheme, fam, densify)).encode()) % (2**31))
    if scheme == "minhash":
        return (Hash2U.create(key, k, s) if fam == "2u"
                else Hash4U.create(key, k, s))
    return OPH.create(key, k, s, fam, densify)


@pytest.mark.parametrize("scheme,fam,densify,b", _GRID)
def test_engine_matches_reference_grid(scheme, fam, densify, b, batch16):
    """Engine output == jnp reference == ref backend, and the packed wire
    format unpacks to the same signatures (b > 0)."""
    s, k = 16, 128
    family = _make_family(scheme, fam, densify, k, s)
    if scheme == "minhash":
        want = np.asarray(minhash_signatures(batch16.indices, batch16.mask,
                                             family))
        if b:
            want = want & ((1 << b) - 1)
    else:
        want = np.asarray(oph_signatures(batch16.indices, batch16.mask,
                                         family, b=b))
    eng = SignatureEngine(family, b=b)
    got = np.asarray(eng.signatures(batch16))
    assert np.array_equal(got, want), "engine vs reference"
    ref = np.asarray(SignatureEngine(family, b=b,
                                     backend="ref").signatures(batch16))
    assert np.array_equal(ref, want), "ref backend vs reference"
    legacy = np.asarray(batch_signatures(batch16, family, b=b))
    assert np.array_equal(legacy, want), "legacy wrapper vs reference"
    if b:
        packed = SignatureEngine(family, b=b,
                                 packed=True).packed_signatures(batch16)
        assert isinstance(packed, PackedSignatures)
        assert packed.sentinel == (densify == "sentinel")
        assert packed.data.shape == \
            (batch16.n, packed_words(k, packed.code_bits))
        assert np.array_equal(np.asarray(packed.unpack()), want), "packed"


def test_engine_perm_base_reference(batch16):
    """Permutation-base OPH routes to the gold-standard jnp reference."""
    oph = OPH.create(jax.random.PRNGKey(3), 32, 10, "perm", "sentinel")
    small = _batch(3, 60, 10, seed=5, max_nnz=64)
    want = oph_signatures(small.indices, small.mask, oph, b=4)
    got = SignatureEngine(oph, b=4).signatures(small)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    packed = SignatureEngine(oph, b=4, packed=True).packed_signatures(small)
    assert np.array_equal(np.asarray(packed.unpack()), np.asarray(want))


def test_packed_signatures_pytree_and_slicing(batch16):
    fam = Hash2U.create(jax.random.PRNGKey(1), 128, 16)
    p = SignatureEngine(fam, b=8, packed=True).packed_signatures(batch16)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == 1
    p2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (p2.k, p2.b, p2.sentinel) == (p.k, p.b, p.sentinel)
    sl = p[1:3]
    assert sl.n == 2 and len(sl) == 2
    assert np.array_equal(np.asarray(sl.unpack()),
                          np.asarray(p.unpack())[1:3])
    assert p.nbytes == p.data.size * 4


# ---------------------------------------------------------------------------
# Backends + tuning table
# ---------------------------------------------------------------------------

def test_backend_registry_and_resolution(batch16):
    assert {"interpret", "tpu", "gpu", "ref"} <= set(BACKENDS)
    auto = resolve_backend(None)
    assert auto.name == ("tpu" if jax.default_backend() == "tpu" else
                         "gpu" if jax.default_backend() == "gpu" else
                         "interpret")
    with pytest.raises(ValueError):
        resolve_backend("cuda9000")
    # gpu entry falls back to the jnp reference until triton lands
    assert not BACKENDS["gpu"].use_pallas
    fam = Hash2U.create(jax.random.PRNGKey(0), 128, 16)
    want = np.asarray(batch_signatures(batch16, fam, b=8))
    got = np.asarray(SignatureEngine(fam, b=8,
                                     backend="gpu").signatures(batch16))
    assert np.array_equal(got, want)


def test_tuning_table_persistence(tmp_path, batch16):
    table = TuningTable()
    table.record("tpu", "minhash", 128, 300,
                 {"blk_n": 16, "blk_t": 512, "blk_k": 128})
    path = table.save(str(tmp_path / "tuning.json"))
    loaded = TuningTable.load(path)
    assert loaded.lookup("tpu", "minhash", 128, 260) == \
        {"blk_n": 16, "blk_t": 512, "blk_k": 128}       # same nnz bucket
    assert loaded.lookup("tpu", "minhash", 128, 1000) is None  # other bucket
    assert loaded.lookup("tpu", "oph", 128, 300) is None       # other scheme
    assert loaded.lookup("interpret", "minhash", 128, 300) is None
    with open(path) as f:
        assert json.load(f)["version"] == 1
    # a table entry actually steers the engine's plan -- and only for its
    # own scheme (blk_k=0 is an OPH-only convention)
    tuned = TuningTable()
    tuned.record("interpret", "minhash", 128, batch16.indices.shape[1],
                 {"blk_n": 4, "blk_t": 64, "blk_k": 128})
    eng = SignatureEngine(Hash2U.create(jax.random.PRNGKey(0), 128, 16),
                          backend="interpret", tuning=tuned)
    plan = eng.plan_for(batch16.indices.shape[1])
    assert (plan.blk_n, plan.blk_t, plan.blk_k) == (4, 64, 128)
    oph_eng = SignatureEngine(OPH.create(jax.random.PRNGKey(0), 128, 16,
                                         "2u", "rotation"),
                              backend="interpret", tuning=tuned)
    assert oph_eng.plan_for(batch16.indices.shape[1]).blk_k == 0
    explicit = SignatureEngine(Hash2U.create(jax.random.PRNGKey(0), 128, 16),
                               blocks={"blk_n": 8, "blk_t": 128,
                                       "blk_k": 128}, tuning=tuned)
    assert explicit.plan_for(999).blk_n == 8            # explicit wins


def test_hamming_scheme_tuning_table_steers_kernel(batch16):
    """The retrieval kernel resolves 'hamming' TuningTable entries (keyed
    on the packed word count) and stays bit-exact under odd blocks."""
    from repro.kernels import packed_match
    fam = Hash2U.create(jax.random.PRNGKey(4), 128, 16)
    wire = SignatureEngine(fam, b=8, packed=True).packed_signatures(batch16)
    want = np.asarray(packed_match(wire.data, wire.data, wire.spec,
                                   backend="interpret"))
    tuned = TuningTable()
    words = wire.data.shape[1]
    tuned.record("interpret", "hamming", 128, words,
                 {"blk_q": 4, "blk_n": 64, "blk_k": 32})
    got = np.asarray(packed_match(wire.data, wire.data, wire.spec,
                                  backend="interpret", tuning=tuned))
    assert np.array_equal(got, want)
    assert tuned.lookup("interpret", "hamming", 128, words) == \
        {"blk_q": 4, "blk_n": 64, "blk_k": 32}


# ---------------------------------------------------------------------------
# .sig shard format + layering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mmap", [False, True])
def test_sig_shard_roundtrip(tmp_path, mmap):
    from repro.data.sigshard import (SigShardMeta, read_sig_meta,
                                     read_sig_shard, write_sig_shard)
    rng = np.random.default_rng(0)
    words = rng.integers(0, 2**32, (37, 36), dtype=np.uint64).astype(np.uint32)
    labels = rng.normal(size=37).astype(np.float32)
    path = str(tmp_path / "chunk.sig")
    meta = write_sig_shard(path, words, labels, k=128, b=8, code_bits=9,
                           sentinel=True)
    assert meta == read_sig_meta(path)
    assert meta.payload_bytes == 37 * 36 * 4
    assert meta.payload_offset % 64 == 0
    w2, l2, m2 = read_sig_shard(path, mmap=mmap)
    assert m2 == SigShardMeta(37, 128, 8, 9, 36, True)
    assert np.array_equal(np.asarray(w2), words)
    assert np.array_equal(l2, labels)
    with pytest.raises(ValueError):
        bad = str(tmp_path / "bad.sig")
        with open(bad, "wb") as f:
            f.write(b"NOPE" + b"\0" * 60)
        read_sig_meta(bad)


def test_sig_shard_version_byte_roundtrip_and_mismatch(tmp_path):
    """The header's version byte survives a write/read round trip, and a
    bumped version fails loudly (clear error naming both versions)."""
    from repro.data.sigshard import VERSION, read_sig_meta, write_sig_shard
    path = str(tmp_path / "v.sig")
    words = np.arange(12, dtype=np.uint32).reshape(3, 4)
    write_sig_shard(path, words, np.zeros(3, np.float32), k=16, b=8,
                    code_bits=8)
    with open(path, "rb") as f:
        blob = bytearray(f.read())
    assert blob[4] == VERSION                            # little-endian u32
    read_sig_meta(path)                                  # current version ok
    blob[4] = VERSION + 41
    with open(path, "wb") as f:
        f.write(blob)
    with pytest.raises(ValueError, match=rf"version {VERSION + 41}.*"
                                         rf"reads version {VERSION}"):
        read_sig_meta(path)


def test_no_pallas_builders_outside_kernels():
    """Layering rule: only repro/kernels/ may touch a *_pallas builder or
    pallas_call (the ``use_pallas=`` keyword is fine everywhere)."""
    import re
    import repro
    builder = re.compile(r"\b(?:minhash|oph|sigbag)\w*_pallas\b"
                         r"|\bpallas_call\b")
    root = list(repro.__path__)[0]
    offenders = []
    for dirpath, _, files in os.walk(root):
        inside_kernels = os.path.basename(dirpath) == "kernels"
        for name in files:
            if not name.endswith(".py") or inside_kernels:
                continue
            with open(os.path.join(dirpath, name)) as f:
                src = f.read()
            if builder.search(src):
                offenders.append(os.path.join(dirpath, name))
    assert not offenders, offenders


def test_tune_accepts_packed_match_scheme():
    """engine.tune() with a PackSpec times packed_match candidates and
    records the winner under scheme "hamming" keyed on the word count."""
    import numpy as np

    from repro.kernels import packed_match
    from repro.kernels.engine import TuningTable, tune
    from repro.kernels.pack import PackSpec

    spec = PackSpec(128, 8)
    rng = np.random.default_rng(0)
    q = rng.integers(0, 2**32, (8, spec.words), dtype=np.uint64) \
        .astype(np.uint32)
    c = rng.integers(0, 2**32, (64, spec.words), dtype=np.uint64) \
        .astype(np.uint32)
    tab = TuningTable()
    candidates = [{"blk_q": 8, "blk_n": 64, "blk_k": 128},
                  {"blk_q": 8, "blk_n": 128, "blk_k": 128}]
    best = tune(spec, (q, c), candidates, iters=1, table=tab,
                backend="interpret")
    assert best in candidates
    assert tab.lookup("interpret", "hamming", spec.k, spec.words) == best
    # the recorded blocks drive packed_match and agree with the oracle
    out = packed_match(q, c, spec, backend="interpret", tuning=tab)
    want = packed_match(q, c, spec, backend="ref")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
