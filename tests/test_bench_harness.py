"""benchmarks/run.py harness semantics: failures must fail the process.

The slow CI tier leans on the harness exit code, so a raising benchmark
module (or a selector that matches nothing) must not exit 0 with a
clean-looking summary.
"""

import sys

import pytest

import benchmarks.run as bench_run


def _run_with(monkeypatch, modules, argv):
    monkeypatch.setattr(bench_run, "MODULES", modules)
    monkeypatch.setattr(sys, "argv", ["benchmarks.run"] + argv)


def test_raising_module_exits_nonzero(monkeypatch, capsys, tmp_path):
    """A module whose run() raises turns into exit code 1, with the
    healthy modules' rows still printed."""
    import types
    good = types.ModuleType("benchmarks.fake_good")
    good.run = lambda: [("good/row", 1.0, {"ok": 1})]
    bad = types.ModuleType("benchmarks.fake_bad")

    def _boom():
        raise RuntimeError("benchmark exploded")
    bad.run = _boom
    monkeypatch.setitem(sys.modules, "benchmarks.fake_good", good)
    monkeypatch.setitem(sys.modules, "benchmarks.fake_bad", bad)
    _run_with(monkeypatch, [("fake_good", "x"), ("fake_bad", "y")], [])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 1
    out, err = capsys.readouterr()
    assert "good/row" in out
    assert "fake_bad FAILED" in err and "FAILURES" in err


def test_empty_selection_exits_nonzero(monkeypatch, capsys):
    """A substring --only matching nothing must not look like success."""
    _run_with(monkeypatch, list(bench_run.MODULES),
              ["--only", "no_such_benchmark"])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 2
    assert "selected no modules" in capsys.readouterr().err


def test_unknown_exact_name_errors(monkeypatch):
    _run_with(monkeypatch, list(bench_run.MODULES),
              ["--only", "search_index,definitely_not_real"])
    with pytest.raises(SystemExit):
        bench_run.main()


def test_search_index_registered():
    assert any(name == "search_index" for name, _ in bench_run.MODULES)


def test_repeat_reports_median_and_json(monkeypatch, capsys, tmp_path):
    """--repeat N runs each module N times and reports the per-row
    MEDIAN wall-clock; --json writes {"rows": ..., "metrics": ...}."""
    import json
    import types
    calls = []
    mod = types.ModuleType("benchmarks.fake_med")

    def _run():
        calls.append(1)
        # deterministic per-call timings: 30, 10, 20 -> median 20
        us = {1: 30.0, 2: 10.0, 3: 20.0}[len(calls)]
        return [("med/row", us, {"payload": len(calls)})]
    mod.run = _run
    monkeypatch.setitem(sys.modules, "benchmarks.fake_med", mod)
    out_json = str(tmp_path / "rows.json")
    _run_with(monkeypatch, [("fake_med", "x")],
              ["--repeat", "3", "--json", out_json])
    bench_run.main()
    assert len(calls) == 3
    out = capsys.readouterr().out
    assert "med/row,20.0" in out                 # median of 30/10/20
    with open(out_json) as f:
        doc = json.load(f)
    assert doc["rows"] == [
        {"name": "med/row", "us_per_call": 20.0, "payload": 3,
         "repeat": 3, "us_min": 10.0, "us_max": 30.0}]
    assert "metrics" in doc                       # per-module obs snapshots


def test_json_metrics_section_captures_registry(monkeypatch, capsys,
                                                tmp_path):
    """A module that touches the obs registry gets a metrics snapshot
    keyed by module name; the registry resets between modules."""
    import json
    import types
    from repro.obs.metrics import get_registry

    mod = types.ModuleType("benchmarks.fake_obs")

    def _run():
        get_registry().counter("bench_fake_total", "test counter").inc(3)
        return [("obs/row", 1.0, {})]
    mod.run = _run
    monkeypatch.setitem(sys.modules, "benchmarks.fake_obs", mod)
    out_json = str(tmp_path / "rows.json")
    _run_with(monkeypatch, [("fake_obs", "x")], ["--json", out_json])
    bench_run.main()
    capsys.readouterr()
    with open(out_json) as f:
        doc = json.load(f)
    snap = doc["metrics"]["fake_obs"]
    assert snap["bench_fake_total"]["samples"][0]["value"] == 3.0


def test_repeat_must_be_positive(monkeypatch):
    _run_with(monkeypatch, list(bench_run.MODULES), ["--repeat", "0"])
    with pytest.raises(SystemExit):
        bench_run.main()


def test_search_scaling_registered():
    assert any(name == "search_scaling" for name, _ in bench_run.MODULES)


def test_search_serving_registered():
    assert any(name == "search_serving" for name, _ in bench_run.MODULES)
