"""benchmarks/run.py harness semantics: failures must fail the process.

The slow CI tier leans on the harness exit code, so a raising benchmark
module (or a selector that matches nothing) must not exit 0 with a
clean-looking summary.
"""

import sys

import pytest

import benchmarks.run as bench_run


def _run_with(monkeypatch, modules, argv):
    monkeypatch.setattr(bench_run, "MODULES", modules)
    monkeypatch.setattr(sys, "argv", ["benchmarks.run"] + argv)


def test_raising_module_exits_nonzero(monkeypatch, capsys, tmp_path):
    """A module whose run() raises turns into exit code 1, with the
    healthy modules' rows still printed."""
    import types
    good = types.ModuleType("benchmarks.fake_good")
    good.run = lambda: [("good/row", 1.0, {"ok": 1})]
    bad = types.ModuleType("benchmarks.fake_bad")

    def _boom():
        raise RuntimeError("benchmark exploded")
    bad.run = _boom
    monkeypatch.setitem(sys.modules, "benchmarks.fake_good", good)
    monkeypatch.setitem(sys.modules, "benchmarks.fake_bad", bad)
    _run_with(monkeypatch, [("fake_good", "x"), ("fake_bad", "y")], [])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 1
    out, err = capsys.readouterr()
    assert "good/row" in out
    assert "fake_bad FAILED" in err and "FAILURES" in err


def test_empty_selection_exits_nonzero(monkeypatch, capsys):
    """A substring --only matching nothing must not look like success."""
    _run_with(monkeypatch, list(bench_run.MODULES),
              ["--only", "no_such_benchmark"])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 2
    assert "selected no modules" in capsys.readouterr().err


def test_unknown_exact_name_errors(monkeypatch):
    _run_with(monkeypatch, list(bench_run.MODULES),
              ["--only", "search_index,definitely_not_real"])
    with pytest.raises(SystemExit):
        bench_run.main()


def test_search_index_registered():
    assert any(name == "search_index" for name, _ in bench_run.MODULES)
