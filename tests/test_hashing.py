"""Property tests for the universal-hash building blocks.

Seeded parametrized sweeps (numpy RNG) instead of hypothesis: each case
draws a large batch of random operands -- including the adversarial
boundary values hypothesis would shrink to -- and checks the exact
arithmetic invariant against 64-bit numpy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import (Hash2U, Hash4U, MERSENNE_P, add64,
                                hash2u_apply, hash4u_apply, mod_mersenne31,
                                mulmod_mersenne31, umul32_wide,
                                PermutationFamily, family_storage_bytes)

# boundary values every sweep mixes in (what hypothesis would find)
_EDGES_U32 = np.array([0, 1, 2, 0xFFFF, 0x10000, 0x7FFFFFFF, 0x80000000,
                       0xFFFFFFFE, 0xFFFFFFFF], np.uint32)
_EDGES_U31 = np.array([0, 1, 2, 0xFFFF, 0x10000, 2**31 - 2, 2**31 - 1],
                      np.uint32)


def _draw(rng, size, hi, edges):
    vals = rng.integers(0, hi, size, dtype=np.uint64).astype(np.uint32)
    vals[: len(edges)] = edges
    return rng.permutation(vals)


@pytest.mark.parametrize("seed", range(3))
def test_umul32_wide_matches_uint64(seed):
    rng = np.random.default_rng(seed)
    a = _draw(rng, 500, 2**32, _EDGES_U32)
    b = _draw(rng, 500, 2**32, _EDGES_U32)
    hi, lo = umul32_wide(jnp.asarray(a), jnp.asarray(b))
    prod = a.astype(np.uint64) * b.astype(np.uint64)
    assert np.array_equal(np.asarray(hi), (prod >> 32).astype(np.uint32))
    assert np.array_equal(np.asarray(lo), (prod & 0xFFFFFFFF).astype(np.uint32))


@pytest.mark.parametrize("seed", range(3))
def test_mod_mersenne31_matches_modulo(seed):
    rng = np.random.default_rng(100 + seed)
    a = _draw(rng, 500, 2**31, _EDGES_U31)
    b = _draw(rng, 500, 2**31, _EDGES_U31)
    got = np.asarray(mulmod_mersenne31(jnp.asarray(a), jnp.asarray(b)))
    want = ((a.astype(np.uint64) * b.astype(np.uint64))
            % np.uint64(2**31 - 1)).astype(np.uint32)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("seed", range(3))
def test_add64_carry(seed):
    rng = np.random.default_rng(200 + seed)
    his = _draw(rng, 200, 2**31, _EDGES_U31)
    los = _draw(rng, 200, 2**32, _EDGES_U32)
    cs = _draw(rng, 200, 2**32, _EDGES_U32)
    h, l = add64(jnp.asarray(his), jnp.asarray(los), jnp.asarray(cs))
    total = (his.astype(object) * 2**32 + los.astype(object)
             + cs.astype(object))
    got = np.asarray(h).astype(object) * 2**32 + np.asarray(l).astype(object)
    assert (got == total).all()


@pytest.mark.parametrize("s", [8, 16, 24, 30])
def test_4u_polynomial_vs_bigint(s):
    key = jax.random.PRNGKey(1)
    h4 = Hash4U.create(key, k=5, s=s)
    rng = np.random.default_rng(0)
    t = rng.integers(0, 2**s, 64, dtype=np.int64)
    out = np.asarray(h4(jnp.asarray(t)))
    A = np.asarray(h4.a).astype(object)
    p = 2**31 - 1
    for i in range(len(t)):
        for j in range(5):
            ti = int(t[i])
            val = (int(A[0, j]) + int(A[1, j]) * ti + int(A[2, j]) * ti**2
                   + int(A[3, j]) * ti**3) % p % (2**s)
            assert out[i, j] == val


@pytest.mark.parametrize("variant", ["high", "low"])
def test_2u_matches_formula(variant):
    key = jax.random.PRNGKey(2)
    f = Hash2U.create(key, k=7, s=20, variant=variant)
    rng = np.random.default_rng(1)
    t = rng.integers(0, 2**20, 100, dtype=np.int64)
    out = np.asarray(f(jnp.asarray(t)))
    a1 = np.asarray(f.a1).astype(np.uint64)
    a2 = np.asarray(f.a2).astype(np.uint64)
    v = (a1[None, :] + a2[None, :] * t[:, None].astype(np.uint64)) % 2**32
    want = (v >> (32 - 20)) if variant == "high" else (v % 2**20)
    assert np.array_equal(out, want.astype(np.uint32))


def test_2u_output_range_and_determinism():
    f = Hash2U.create(jax.random.PRNGKey(0), k=16, s=10)
    t = jnp.arange(1000)
    o1, o2 = f(t), f(t)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert int(jnp.max(o1)) < 2**10


def test_storage_accounting():
    key = jax.random.PRNGKey(0)
    D, k = 2**14, 100
    perm = PermutationFamily.create(key, k, D)
    h2 = Hash2U.create(key, k, 16)
    h4 = Hash4U.create(key, k, 16)
    assert family_storage_bytes(perm) == k * D * 4
    assert family_storage_bytes(h2) == 2 * k * 4
    assert family_storage_bytes(h4) == 4 * k * 4
    # the paper's Issue 3: permutations are >> hash coefficients
    assert family_storage_bytes(perm) > 1000 * family_storage_bytes(h4)
