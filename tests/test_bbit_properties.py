"""Property tests for b-bit packing / expansion / elastic.

Seeded parametrized sweeps (numpy RNG) instead of hypothesis: the same
invariants, exercised over deterministic grids of (k, b) covering the
word boundaries hypothesis used to hunt for.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bbit import (expand_onehot, expand_tokens, lowest_bits,
                             pack_signatures, raw_storage_bits, storage_bits,
                             unpack_signatures, vw_storage_bits)


@pytest.mark.parametrize("b", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("k", [1, 7, 16, 31, 32, 33])
def test_pack_unpack_roundtrip(k, b):
    rng = np.random.default_rng(k * 37 + b)
    sig = jnp.asarray(rng.integers(0, 1 << b, (3, k)), jnp.uint32)
    packed = pack_signatures(sig, b)
    got = unpack_signatures(packed, b, k)
    assert np.array_equal(np.asarray(got), np.asarray(sig))
    # storage really is ceil(k*b/32) words
    assert packed.shape[1] == -(-k * b // 32)


@pytest.mark.parametrize("b", [1, 2, 3, 8, 12])
@pytest.mark.parametrize("k", [2, 5, 16])
def test_expansion_has_exactly_k_ones(b, k):
    rng = np.random.default_rng(b * 100 + k)
    sig = jnp.asarray(rng.integers(0, 1 << b, (2, k)), jnp.uint32)
    oh = np.asarray(expand_onehot(sig, b))
    assert oh.shape == (2, k * (1 << b))
    assert (oh.sum(axis=1) == k).all()
    # inner product == match count (Eq. 5)
    matches = int((np.asarray(sig[0]) == np.asarray(sig[1])).sum())
    assert int(oh[0] @ oh[1]) == matches


@pytest.mark.parametrize("b", [1, 2, 8, 16])
@pytest.mark.parametrize("k", [1, 7, 33, 64])
def test_tokens_are_block_disjoint(b, k):
    rng = np.random.default_rng(k)
    sig = jnp.asarray(rng.integers(0, 1 << b, (1, k)), jnp.uint32)
    tok = np.asarray(expand_tokens(sig, b))[0]
    blocks = tok >> b
    assert np.array_equal(blocks, np.arange(k))


def test_lowest_bits_range():
    sig = jnp.asarray([[0xFFFFFFFF, 0, 12345]], jnp.uint32)
    for b in (1, 4, 8, 31):
        out = np.asarray(lowest_bits(sig, b))
        assert out.max() < (1 << b)


def test_storage_model_ordering():
    """b-bit storage << raw and << VW-at-parity (paper Figs 10-12)."""
    bbit = storage_bits(k=500, b=8)                  # 4,000 bits/example
    assert bbit < raw_storage_bits(avg_nnz=12062) / 90
    assert bbit < vw_storage_bits(m_bins=16384) / 100


def test_elastic_reshard_changes_mesh(tmp_path):
    """Checkpoint saved unsharded restores under a 2-device mesh (elastic
    scale-up) and values survive."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.train import checkpoint, reshard_restore
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, state)
    mesh = Mesh(np.array(jax.devices()[:2]).reshape(2, 1), ("data", "model"))

    def sharding_fn(template):
        return {"w": NamedSharding(mesh, P("data", None))}

    restored, step = reshard_restore(d, state, sharding_fn)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert len(restored["w"].sharding.device_set) == 2
